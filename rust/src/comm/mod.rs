//! Communication fabric for partition-parallel training.
//!
//! [`Transport`] is the message-passing contract the training schedule is
//! written against: tagged sends, blocking tagged receives, and per-rank
//! byte accounting. Two implementations exist:
//!
//! * [`Fabric`] (here) — an in-process mailbox with per-pair byte
//!   accounting, shared by every rank of a sequential or threaded run.
//!   Experiments get exact communication volumes "for free"; those byte
//!   counts feed the [`crate::sim`] link model to estimate what the same
//!   schedule costs on the paper's testbeds.
//! * [`crate::net::TcpTransport`] — real length-prefixed frames over
//!   localhost TCP sockets, one instance per OS process (one rank each).
//!
//! Staleness is encoded in [`Tag`]s, so the same schedule is
//! deterministic over either transport.

pub mod allreduce;
pub mod topology;

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Which tensor a message carries (Algorithm 1's two comm streams).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// boundary features, forward pass (thread_f in Alg. 1)
    FwdFeat,
    /// boundary feature gradients, backward pass (thread_b in Alg. 1)
    BwdGrad,
    /// model-gradient all-reduce chunks
    Reduce,
    /// control/setup (boundary-set exchange)
    Setup,
}

/// Message identity: (iteration, layer, phase). PipeGCN tags messages
/// with the *producing* iteration so the consumer can explicitly pick up
/// iteration `t-1` tensors — staleness is in the tag, not in timing luck.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag {
    pub iter: u32,
    pub layer: u16,
    pub phase: Phase,
}

impl Phase {
    /// Stable wire encoding (used by `net::frame`).
    pub fn code(self) -> u8 {
        match self {
            Phase::FwdFeat => 0,
            Phase::BwdGrad => 1,
            Phase::Reduce => 2,
            Phase::Setup => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Phase> {
        match c {
            0 => Some(Phase::FwdFeat),
            1 => Some(Phase::BwdGrad),
            2 => Some(Phase::Reduce),
            3 => Some(Phase::Setup),
            _ => None,
        }
    }
}

impl Tag {
    pub fn new(iter: u32, layer: u16, phase: Phase) -> Tag {
        Tag { iter, layer, phase }
    }
}

/// The message-passing contract the training schedule runs over,
/// extracted from the [`Fabric`] API: tagged f32 payloads between ranks,
/// FIFO per (src, dst, tag), with per-rank payload-byte accounting.
///
/// A shared implementation ([`Fabric`]) serves every rank of an
/// in-process run; a per-process implementation
/// ([`crate::net::TcpTransport`]) serves exactly one rank and may panic
/// if asked to send as (or receive for) a rank it does not own.
pub trait Transport: Send + Sync {
    fn n_ranks(&self) -> usize;

    /// Send `payload` from `src` to `dst` under `tag`. Never blocks on
    /// the consumer (queued in-process, or handed to a writer thread).
    fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>);

    /// Blocking receive of the oldest (src → dst, tag) message.
    fn recv_blocking(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32>;

    /// Total payload bytes rank `src` has sent so far (4 bytes per f32;
    /// framing overhead excluded so volumes are comparable across
    /// transports).
    fn bytes_sent(&self, src: usize) -> u64;
}

impl Transport for Fabric {
    fn n_ranks(&self) -> usize {
        Fabric::n_ranks(self)
    }

    fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>) {
        Fabric::send(self, src, dst, tag, payload)
    }

    fn recv_blocking(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        Fabric::recv_blocking(self, src, dst, tag)
    }

    fn bytes_sent(&self, src: usize) -> u64 {
        let g = self.inner.lock().unwrap();
        g.bytes[src].iter().sum()
    }
}

/// Pack `u32` values (node ids, control words) into the f32 payload
/// channel bit-for-bit. No float arithmetic ever touches payloads in
/// transit (both transports move raw bit patterns), so this is lossless
/// even for patterns that alias NaNs.
pub fn encode_u32s(vals: &[u32]) -> Vec<f32> {
    vals.iter().map(|&v| f32::from_bits(v)).collect()
}

pub fn decode_u32s(payload: &[f32]) -> Vec<u32> {
    payload.iter().map(|v| v.to_bits()).collect()
}

/// Pack `f64` values (loss curves) into the f32 payload channel as two
/// bit-halves each — lossless, so cross-process loss aggregation stays
/// bit-identical to the in-process engines.
pub fn encode_f64s(vals: &[f64]) -> Vec<f32> {
    let mut out = Vec::with_capacity(vals.len() * 2);
    for &v in vals {
        let bits = v.to_bits();
        out.push(f32::from_bits((bits >> 32) as u32));
        out.push(f32::from_bits(bits as u32));
    }
    out
}

pub fn decode_f64s(payload: &[f32]) -> Vec<f64> {
    assert_eq!(payload.len() % 2, 0, "f64 payload must have even length");
    payload
        .chunks_exact(2)
        .map(|c| f64::from_bits(((c[0].to_bits() as u64) << 32) | c[1].to_bits() as u64))
        .collect()
}

#[derive(Default)]
struct FabricInner {
    /// queues[(src, dst)][tag] — FIFO per (pair, tag)
    queues: HashMap<(u32, u32), HashMap<Tag, VecDeque<Vec<f32>>>>,
    /// bytes[src][dst]
    bytes: Vec<Vec<u64>>,
    /// messages[src][dst]
    msgs: Vec<Vec<u64>>,
}

/// In-process fabric between `n` ranks. Thread-safe; `recv_blocking`
/// parks on a condvar so a threaded runner can genuinely overlap.
pub struct Fabric {
    n: usize,
    inner: Mutex<FabricInner>,
    cv: Condvar,
}

impl Fabric {
    pub fn new(n: usize) -> Fabric {
        Fabric {
            n,
            inner: Mutex::new(FabricInner {
                queues: HashMap::new(),
                bytes: vec![vec![0; n]; n],
                msgs: vec![vec![0; n]; n],
            }),
            cv: Condvar::new(),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Send `payload` from `src` to `dst` under `tag`.
    pub fn send(&self, src: usize, dst: usize, tag: Tag, payload: Vec<f32>) {
        assert!(src < self.n && dst < self.n);
        let mut g = self.inner.lock().unwrap();
        g.bytes[src][dst] += (payload.len() * 4) as u64;
        g.msgs[src][dst] += 1;
        g.queues
            .entry((src as u32, dst as u32))
            .or_default()
            .entry(tag)
            .or_default()
            .push_back(payload);
        self.cv.notify_all();
    }

    /// Non-blocking receive of the oldest message (src→dst, tag).
    pub fn try_recv(&self, src: usize, dst: usize, tag: Tag) -> Option<Vec<f32>> {
        let mut g = self.inner.lock().unwrap();
        g.queues
            .get_mut(&(src as u32, dst as u32))
            .and_then(|m| m.get_mut(&tag))
            .and_then(|q| q.pop_front())
    }

    /// Blocking receive (threaded runner).
    pub fn recv_blocking(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(v) = g
                .queues
                .get_mut(&(src as u32, dst as u32))
                .and_then(|m| m.get_mut(&tag))
                .and_then(|q| q.pop_front())
            {
                return v;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Receive that must succeed immediately (sequential trainer, where
    /// the producer already ran). Panics with a diagnostic otherwise.
    pub fn recv_now(&self, src: usize, dst: usize, tag: Tag) -> Vec<f32> {
        self.try_recv(src, dst, tag)
            .unwrap_or_else(|| panic!("no message {src}->{dst} for {tag:?}"))
    }

    /// Total bytes sent src→dst so far.
    pub fn bytes(&self, src: usize, dst: usize) -> u64 {
        self.inner.lock().unwrap().bytes[src][dst]
    }

    /// Full byte matrix snapshot.
    pub fn byte_matrix(&self) -> Vec<Vec<u64>> {
        self.inner.lock().unwrap().bytes.clone()
    }

    pub fn total_bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes.iter().flatten().sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.inner.lock().unwrap().msgs.iter().flatten().sum()
    }

    /// Reset byte/message counters (keep queued messages).
    pub fn reset_counters(&self) {
        let mut g = self.inner.lock().unwrap();
        for row in g.bytes.iter_mut() {
            row.iter_mut().for_each(|b| *b = 0);
        }
        for row in g.msgs.iter_mut() {
            row.iter_mut().for_each(|b| *b = 0);
        }
    }

    /// Number of messages still queued (tests: catch leaks / wrong tags).
    pub fn pending(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.queues.values().flat_map(|m| m.values()).map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_fifo_per_tag() {
        let f = Fabric::new(2);
        let t = Tag::new(1, 0, Phase::FwdFeat);
        f.send(0, 1, t, vec![1.0]);
        f.send(0, 1, t, vec![2.0]);
        assert_eq!(f.try_recv(0, 1, t), Some(vec![1.0]));
        assert_eq!(f.try_recv(0, 1, t), Some(vec![2.0]));
        assert_eq!(f.try_recv(0, 1, t), None);
    }

    #[test]
    fn tags_isolate_messages() {
        let f = Fabric::new(2);
        let t1 = Tag::new(1, 0, Phase::FwdFeat);
        let t2 = Tag::new(1, 0, Phase::BwdGrad);
        let t3 = Tag::new(2, 0, Phase::FwdFeat);
        f.send(0, 1, t1, vec![1.0]);
        f.send(0, 1, t2, vec![2.0]);
        f.send(0, 1, t3, vec![3.0]);
        assert_eq!(f.try_recv(0, 1, t3), Some(vec![3.0]));
        assert_eq!(f.try_recv(0, 1, t1), Some(vec![1.0]));
        assert_eq!(f.try_recv(0, 1, t2), Some(vec![2.0]));
    }

    #[test]
    fn byte_accounting() {
        let f = Fabric::new(3);
        let t = Tag::new(0, 0, Phase::Setup);
        f.send(0, 2, t, vec![0.0; 10]);
        f.send(2, 0, t, vec![0.0; 5]);
        assert_eq!(f.bytes(0, 2), 40);
        assert_eq!(f.bytes(2, 0), 20);
        assert_eq!(f.total_bytes(), 60);
        assert_eq!(f.total_msgs(), 2);
        f.reset_counters();
        assert_eq!(f.total_bytes(), 0);
        // queued messages survive the counter reset
        assert_eq!(f.pending(), 2);
    }

    #[test]
    fn blocking_recv_across_threads() {
        use std::sync::Arc;
        let f = Arc::new(Fabric::new(2));
        let t = Tag::new(5, 1, Phase::FwdFeat);
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.recv_blocking(0, 1, t));
        std::thread::sleep(std::time::Duration::from_millis(20));
        f.send(0, 1, t, vec![7.0]);
        assert_eq!(h.join().unwrap(), vec![7.0]);
    }

    #[test]
    #[should_panic(expected = "no message")]
    fn recv_now_panics_when_empty() {
        let f = Fabric::new(2);
        f.recv_now(0, 1, Tag::new(0, 0, Phase::FwdFeat));
    }

    #[test]
    fn u32_payload_roundtrip_including_nan_patterns() {
        let vals = vec![0, 1, 0x7FC0_0001, u32::MAX, 0x8000_0000];
        assert_eq!(decode_u32s(&encode_u32s(&vals)), vals);
    }

    #[test]
    fn f64_payload_roundtrip_is_bit_exact() {
        let vals = vec![0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, 6.02214076e23, -1.5e-300];
        let back = decode_f64s(&encode_f64s(&vals));
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn phase_codes_roundtrip() {
        for p in [Phase::FwdFeat, Phase::BwdGrad, Phase::Reduce, Phase::Setup] {
            assert_eq!(Phase::from_code(p.code()), Some(p));
        }
        assert_eq!(Phase::from_code(9), None);
    }

    #[test]
    fn fabric_implements_transport() {
        let f = Fabric::new(2);
        let t: &dyn Transport = &f;
        let tag = Tag::new(3, 1, Phase::FwdFeat);
        t.send(0, 1, tag, vec![1.0, 2.0]);
        assert_eq!(t.recv_blocking(0, 1, tag), vec![1.0, 2.0]);
        assert_eq!(t.bytes_sent(0), 8);
        assert_eq!(t.bytes_sent(1), 0);
        assert_eq!(t.n_ranks(), 2);
    }
}
