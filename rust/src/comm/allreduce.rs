//! Ring all-reduce (reduce-scatter + all-gather) over the [`Fabric`].
//!
//! Used for the model-gradient synchronization (Alg. 1 line 32). The
//! sequential trainer drives all ranks' steps in order; the algorithm is
//! the standard 2(n−1)-step ring so the byte counters reflect exactly
//! what NCCL-style collectives would move: `2·(n−1)/n · bytes` per rank.

use super::schedule::{self, Event, Style};
use super::{Fabric, Phase, Tag};
use crate::util::error::Result;

/// Tag of one ring step (reduce-scatter steps `0..n-1`, then all-gather
/// steps `n-1..2(n-1)`), shared by both all-reduce implementations.
///
/// Each rank sends exactly one message per step (to its successor), so
/// the step index alone disambiguates every message of an iteration —
/// the chunk id is implied by `(step, src)` and stays out of the tag.
/// The previous scheme packed `step·n + chunk` (up to `2n²`) into the
/// u16 layer field, which silently wrapped around from n ≈ 182 ranks;
/// steps top out at `2(n-1)`, and the unrepresentable case (n > 32769)
/// is an `Err` the schedule generator rejects statically — the runtime
/// propagates it instead of panicking.
pub fn step_tag(iter: u32, step: usize, n: usize) -> Result<Tag> {
    let steps = 2 * (n - 1);
    if steps > u16::MAX as usize + 1 {
        return Err(format!(
            "ring all-reduce over {n} ranks needs {steps} step tags (iteration {iter}, \
             step {step}), which cannot fit the u16 tag layer field"
        )
        .into());
    }
    debug_assert!(step < steps, "step {step} out of range for {n} ranks");
    Ok(Tag::new(iter, step as u16, Phase::Reduce))
}

/// Run ring all-reduce over `bufs` (one buffer per rank, all same length),
/// leaving every buffer equal to the elementwise sum. Convenience wrapper
/// that generates the per-rank [`Style::Inline`] ring events itself; the
/// trainer passes its schedule's ring segments to
/// [`ring_allreduce_events`] directly.
pub fn ring_allreduce(fabric: &Fabric, bufs: &mut [Vec<f32>], iter: u32) -> Result<()> {
    let n = bufs.len();
    let events: Vec<Vec<Event>> = (0..n)
        .map(|r| schedule::ring_events(Style::Inline, iter, r, n))
        .collect::<Result<_>>()?;
    let segs: Vec<&[Event]> = events.iter().map(|e| e.as_slice()).collect();
    ring_allreduce_events(fabric, bufs, &segs);
    Ok(())
}

/// The sequential-replay ring executor: drives all ranks' steps in
/// program order, taking every (peer, tag) from the rank's IR segment
/// (`segs[r]`, the [`Style::Inline`] layout of [`schedule::ring_events`]
/// — `Send`, `PostRecv`, `Claim` per step). The chunk arithmetic stays
/// here; message identity comes from the schedule.
pub fn ring_allreduce_events(fabric: &Fabric, bufs: &mut [Vec<f32>], segs: &[&[Event]]) {
    let n = bufs.len();
    assert_eq!(fabric.n_ranks(), n);
    if n <= 1 {
        return;
    }
    assert_eq!(segs.len(), n);
    assert!(segs.iter().all(|s| s.len() == 3 * 2 * (n - 1)));
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return;
    }
    // chunk boundaries: chunk c = [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk = |c: usize| starts[c % n]..starts[c % n + 1];
    let send_of = |r: usize, s: usize| match segs[r][3 * s] {
        Event::Send { dst, tag } => (dst, tag),
        other => panic!("ring schedule: expected a send at step {s}, got {other:?}"),
    };
    let recv_of = |r: usize, s: usize| match segs[r][3 * s + 1] {
        Event::PostRecv { src, tag } => (src, tag),
        other => panic!("ring schedule: expected a posted receive at step {s}, got {other:?}"),
    };

    // reduce-scatter: step s, rank r sends chunk (r - s) to r+1
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + n - s) % n;
            let (dst, tag) = send_of(r, s);
            fabric.send(r, dst, tag, bufs[r][chunk(c)].to_vec());
        }
        for r in 0..n {
            let (src, tag) = recv_of(r, s);
            let c = (src + n - s) % n;
            let recv = fabric.recv_now(src, r, tag);
            for (dst, v) in bufs[r][chunk(c)].iter_mut().zip(recv) {
                *dst += v;
            }
        }
    }
    // all-gather: step s, rank r sends its completed chunk (r + 1 - s)
    for s in 0..n - 1 {
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (dst, tag) = send_of(r, n - 1 + s);
            fabric.send(r, dst, tag, bufs[r][chunk(c)].to_vec());
        }
        for r in 0..n {
            let (src, tag) = recv_of(r, n - 1 + s);
            let c = (src + 1 + n - s) % n;
            let recv = fabric.recv_now(src, r, tag);
            bufs[r][chunk(c)].copy_from_slice(&recv);
        }
    }
}

/// Bytes each rank sends in a ring all-reduce of `elem_count` f32s.
pub fn ring_bytes_per_rank(n: usize, elem_count: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    // 2(n-1) steps, ~elem/n each
    (2 * (n - 1) * (elem_count * 4 / n)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn allreduce_matches_sum() {
        prop::check("ring==sum", 12, |rng| {
            let n = 2 + rng.gen_range(6);
            let len = 1 + rng.gen_range(40);
            let fabric = Fabric::new(n);
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
            let mut want = vec![0.0f32; len];
            for b in &bufs {
                for (w, &v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            ring_allreduce(&fabric, &mut bufs, 0).unwrap();
            for (r, b) in bufs.iter().enumerate() {
                prop::assert_close(b, &want, 1e-4)
                    .map_err(|e| format!("rank {r}: {e}"))?;
            }
            prop_assert!(fabric.pending() == 0, "leaked {} messages", fabric.pending());
            Ok(())
        });
    }

    #[test]
    fn single_rank_noop() {
        let fabric = Fabric::new(1);
        let mut bufs = vec![vec![1.0, 2.0]];
        ring_allreduce(&fabric, &mut bufs, 0).unwrap();
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(fabric.total_bytes(), 0);
    }

    #[test]
    fn byte_volume_matches_formula() {
        let n = 4;
        let len = 80; // divisible by n so the formula is exact
        let fabric = Fabric::new(n);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; len]).collect();
        ring_allreduce(&fabric, &mut bufs, 0).unwrap();
        let per_rank = ring_bytes_per_rank(n, len);
        for r in 0..n {
            let sent: u64 = (0..n).map(|d| fabric.bytes(r, d)).sum();
            assert_eq!(sent, per_rank, "rank {r}");
        }
    }

    #[test]
    fn uneven_length_still_correct() {
        let n = 3;
        let len = 7; // not divisible
        let fabric = Fabric::new(n);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![(r + 1) as f32; len]).collect();
        ring_allreduce(&fabric, &mut bufs, 1).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&v| (v - 6.0).abs() < 1e-6));
        }
        assert_eq!(fabric.pending(), 0);
    }

    /// Regression: at n ≥ 182 the old `step·n + chunk` tags overflowed
    /// the u16 layer field; step-indexed tags must stay correct well
    /// past that boundary.
    #[test]
    fn tag_boundary_many_ranks_still_sums() {
        let n = 300; // n² ≈ 90 000 > u16::MAX
        let len = 2 * n + 7;
        let fabric = Fabric::new(n);
        // halves and small integers: 300-way f32 sums stay exact
        let mut bufs: Vec<Vec<f32>> =
            (0..n).map(|r| vec![(r % 7) as f32 + 0.5; len]).collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (w, &v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        ring_allreduce(&fabric, &mut bufs, 3).unwrap();
        for (r, b) in bufs.iter().enumerate() {
            prop::assert_close(b, &want, 1e-4).unwrap_or_else(|e| panic!("rank {r}: {e}"));
        }
        assert_eq!(fabric.pending(), 0);
    }

    #[test]
    fn step_tags_fit_and_are_per_step_unique() {
        for n in [2usize, 182, 300, 32769] {
            let mut seen = std::collections::HashSet::new();
            for s in 0..2 * (n - 1) {
                assert!(
                    seen.insert(step_tag(7, s, n).unwrap()),
                    "n={n}: duplicate tag at step {s}"
                );
            }
        }
    }

    #[test]
    fn step_tag_rejects_unrepresentable_rank_count() {
        let err = step_tag(2, 0, 40_000).unwrap_err().to_string();
        assert!(err.contains("cannot fit"), "{err}");
        for needle in ["40000", "79998", "iteration 2", "step 0"] {
            assert!(err.contains(needle), "missing {needle:?} in {err}");
        }
    }

    #[test]
    fn empty_buffers_noop() {
        let fabric = Fabric::new(3);
        let mut bufs = vec![vec![], vec![], vec![]];
        ring_allreduce(&fabric, &mut bufs, 0).unwrap();
        assert_eq!(fabric.total_bytes(), 0);
    }
}
