//! Ring all-reduce (reduce-scatter + all-gather) over the [`Fabric`].
//!
//! Used for the model-gradient synchronization (Alg. 1 line 32). The
//! sequential trainer drives all ranks' steps in order; the algorithm is
//! the standard 2(n−1)-step ring so the byte counters reflect exactly
//! what NCCL-style collectives would move: `2·(n−1)/n · bytes` per rank.

use super::{Fabric, Phase, Tag};

/// Tag of one ring step (reduce-scatter steps `0..n-1`, then all-gather
/// steps `n-1..2(n-1)`), shared by both all-reduce implementations.
///
/// Each rank sends exactly one message per step (to its successor), so
/// the step index alone disambiguates every message of an iteration —
/// the chunk id is implied by `(step, src)` and stays out of the tag.
/// The previous scheme packed `step·n + chunk` (up to `2n²`) into the
/// u16 layer field, which silently wrapped around from n ≈ 182 ranks;
/// steps top out at `2(n-1)`, and the unrepresentable case (n > 32769)
/// now fails loudly instead.
pub fn step_tag(iter: u32, step: usize, n: usize) -> Tag {
    let steps = 2 * (n - 1);
    assert!(
        steps <= u16::MAX as usize + 1,
        "ring all-reduce over {n} ranks needs {steps} step tags, \
         which cannot fit the u16 tag layer field"
    );
    debug_assert!(step < steps, "step {step} out of range for {n} ranks");
    Tag::new(iter, step as u16, Phase::Reduce)
}

/// Run ring all-reduce over `bufs` (one buffer per rank, all same length),
/// leaving every buffer equal to the elementwise sum. Message traffic goes
/// through `fabric` (tagged `Phase::Reduce`, iteration `iter`).
pub fn ring_allreduce(fabric: &Fabric, bufs: &mut [Vec<f32>], iter: u32) {
    let n = bufs.len();
    assert_eq!(fabric.n_ranks(), n);
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return;
    }
    // chunk boundaries: chunk c = [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk = |c: usize| starts[c % n]..starts[c % n + 1];

    // reduce-scatter: step s, rank r sends chunk (r - s) to r+1
    for s in 0..n - 1 {
        let tag = step_tag(iter, s, n);
        for r in 0..n {
            let c = (r + n - s) % n;
            let payload = bufs[r][chunk(c)].to_vec();
            fabric.send(r, (r + 1) % n, tag, payload);
        }
        for r in 0..n {
            let src = (r + n - 1) % n;
            let c = (src + n - s) % n;
            let recv = fabric.recv_now(src, r, tag);
            for (dst, v) in bufs[r][chunk(c)].iter_mut().zip(recv) {
                *dst += v;
            }
        }
    }
    // all-gather: step s, rank r sends its completed chunk (r + 1 - s)
    for s in 0..n - 1 {
        let tag = step_tag(iter, n - 1 + s, n);
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let payload = bufs[r][chunk(c)].to_vec();
            fabric.send(r, (r + 1) % n, tag, payload);
        }
        for r in 0..n {
            let src = (r + n - 1) % n;
            let c = (src + 1 + n - s) % n;
            let recv = fabric.recv_now(src, r, tag);
            bufs[r][chunk(c)].copy_from_slice(&recv);
        }
    }
}

/// Bytes each rank sends in a ring all-reduce of `elem_count` f32s.
pub fn ring_bytes_per_rank(n: usize, elem_count: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    // 2(n-1) steps, ~elem/n each
    (2 * (n - 1) * (elem_count * 4 / n)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn allreduce_matches_sum() {
        prop::check("ring==sum", 12, |rng| {
            let n = 2 + rng.gen_range(6);
            let len = 1 + rng.gen_range(40);
            let fabric = Fabric::new(n);
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
            let mut want = vec![0.0f32; len];
            for b in &bufs {
                for (w, &v) in want.iter_mut().zip(b) {
                    *w += v;
                }
            }
            ring_allreduce(&fabric, &mut bufs, 0);
            for (r, b) in bufs.iter().enumerate() {
                prop::assert_close(b, &want, 1e-4)
                    .map_err(|e| format!("rank {r}: {e}"))?;
            }
            prop_assert!(fabric.pending() == 0, "leaked {} messages", fabric.pending());
            Ok(())
        });
    }

    #[test]
    fn single_rank_noop() {
        let fabric = Fabric::new(1);
        let mut bufs = vec![vec![1.0, 2.0]];
        ring_allreduce(&fabric, &mut bufs, 0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(fabric.total_bytes(), 0);
    }

    #[test]
    fn byte_volume_matches_formula() {
        let n = 4;
        let len = 80; // divisible by n so the formula is exact
        let fabric = Fabric::new(n);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; len]).collect();
        ring_allreduce(&fabric, &mut bufs, 0);
        let per_rank = ring_bytes_per_rank(n, len);
        for r in 0..n {
            let sent: u64 = (0..n).map(|d| fabric.bytes(r, d)).sum();
            assert_eq!(sent, per_rank, "rank {r}");
        }
    }

    #[test]
    fn uneven_length_still_correct() {
        let n = 3;
        let len = 7; // not divisible
        let fabric = Fabric::new(n);
        let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![(r + 1) as f32; len]).collect();
        ring_allreduce(&fabric, &mut bufs, 1);
        for b in &bufs {
            assert!(b.iter().all(|&v| (v - 6.0).abs() < 1e-6));
        }
        assert_eq!(fabric.pending(), 0);
    }

    /// Regression: at n ≥ 182 the old `step·n + chunk` tags overflowed
    /// the u16 layer field; step-indexed tags must stay correct well
    /// past that boundary.
    #[test]
    fn tag_boundary_many_ranks_still_sums() {
        let n = 300; // n² ≈ 90 000 > u16::MAX
        let len = 2 * n + 7;
        let fabric = Fabric::new(n);
        // halves and small integers: 300-way f32 sums stay exact
        let mut bufs: Vec<Vec<f32>> =
            (0..n).map(|r| vec![(r % 7) as f32 + 0.5; len]).collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (w, &v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        ring_allreduce(&fabric, &mut bufs, 3);
        for (r, b) in bufs.iter().enumerate() {
            prop::assert_close(b, &want, 1e-4).unwrap_or_else(|e| panic!("rank {r}: {e}"));
        }
        assert_eq!(fabric.pending(), 0);
    }

    #[test]
    fn step_tags_fit_and_are_per_step_unique() {
        for n in [2usize, 182, 300, 32769] {
            let mut seen = std::collections::HashSet::new();
            for s in 0..2 * (n - 1) {
                assert!(seen.insert(step_tag(7, s, n)), "n={n}: duplicate tag at step {s}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "cannot fit")]
    fn step_tag_rejects_unrepresentable_rank_count() {
        let _ = step_tag(0, 0, 40_000);
    }

    #[test]
    fn empty_buffers_noop() {
        let fabric = Fabric::new(3);
        let mut bufs = vec![vec![], vec![], vec![]];
        ring_allreduce(&fabric, &mut bufs, 0);
        assert_eq!(fabric.total_bytes(), 0);
    }
}
