//! Declarative IR of the per-rank communication schedule, plus the
//! static analyzer (`pipegcn check`) and the runtime conformance hooks.
//!
//! PipeGCN's correctness story is tag discipline: staleness lives in
//! message [`Tag`]s, not timing, which is why loss curves are
//! bit-identical across engines. This module makes that discipline an
//! *object*: [`epoch_window`] / [`setup_window`] / [`ring_events`]
//! generate, from `(parts, variant, layers, epochs, boundary plan)`, the
//! exact per-rank sequence of [`Event`]s — `PostRecv` / `Send` / `Wait`
//! / `Claim` — that an engine performs. Both executors
//! (`coordinator::threaded::run_rank_ctl` and the sequential replay in
//! `coordinator::trainer`) consume this IR instead of re-deriving tags
//! inline, so there is one source of truth for execution *and* analysis:
//!
//! * [`verify`] statically checks a full [`Schedule`] — matching (every
//!   posted receive fulfilled by exactly one send, no orphans, no double
//!   claims), tag aliasing (no two live messages on one (src, dst) link
//!   share a tag), deadlock-freedom (the cross-rank wait-for relation
//!   can always make progress), the paper's staleness bound (pipelined
//!   receives used exactly 1 epoch after their producing iteration,
//!   vanilla exactly 0), and handle hygiene (every receive posted in an
//!   epoch window is claimed in that window).
//! * [`Conformance`] cross-checks a *live* engine against the IR under
//!   `debug_assertions` (`PIPEGCN_CONFORMANCE=1`): every transport-level
//!   operation is compared, in per-rank order, against the generated
//!   events, and any divergence panics with the full diagnostic.
//!
//! What the analyzer proves holds for any transport, thread count, or
//! chaos profile — those change *when* messages move, never which tag a
//! payload resolves to. What it cannot see is payload content or kernel
//! math; the bit-identity oracles in `tests/` keep covering that.
//!
//! The greedy simulation in [`verify`] lets every rank run as far as its
//! inbound messages allow (progress is monotone: sends and posts only
//! accumulate), which is sound and complete for deadlock detection but
//! more permissive about interleavings than the sequential engine's
//! lockstep replay — conformance mode pins the real engines to the
//! event *order*, the analyzer pins the event *set and matching*.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::allreduce::step_tag;
use super::{Phase, Tag};
use crate::util::error::Result;
use crate::util::json::Json;

/// Which executor's event order a schedule models. The two engines move
/// the same messages under the same tags but sequence the receive side
/// differently, and conformance is exact, so each gets its own IR.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    /// `run_rank_ctl` (threaded / TCP): every receive of the epoch is
    /// posted up front, then blocking-`Wait`ed at its point of use.
    Prefetched,
    /// the sequential replay in `trainer`: producers run earlier in
    /// program order, so receives are posted and immediately `Claim`ed.
    Inline,
}

/// One transport-level operation of a rank's schedule. `use_epoch` on
/// the receive sides records the epoch whose *compute* consumes the
/// payload — `use_epoch - tag.iter` is the staleness the analyzer
/// checks against the variant's bound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// post a receive for (src → this rank, tag)
    PostRecv { src: usize, tag: Tag },
    /// send this rank's payload to dst under tag
    Send { dst: usize, tag: Tag },
    /// block until the posted (src, tag) receive completes, claim it
    Wait { src: usize, tag: Tag, use_epoch: u32 },
    /// claim a posted (src, tag) receive that must already be complete
    Claim { src: usize, tag: Tag, use_epoch: u32 },
}

impl Event {
    pub fn tag(&self) -> Tag {
        match *self {
            Event::PostRecv { tag, .. }
            | Event::Send { tag, .. }
            | Event::Wait { tag, .. }
            | Event::Claim { tag, .. } => tag,
        }
    }

    /// The other endpoint: src for receive-side events, dst for sends.
    pub fn peer(&self) -> usize {
        match *self {
            Event::PostRecv { src, .. } | Event::Wait { src, .. } | Event::Claim { src, .. } => {
                src
            }
            Event::Send { dst, .. } => dst,
        }
    }

    pub fn kind(&self) -> OpKind {
        match self {
            Event::PostRecv { .. } => OpKind::PostRecv,
            Event::Send { .. } => OpKind::Send,
            Event::Wait { .. } => OpKind::Wait,
            Event::Claim { .. } => OpKind::Claim,
        }
    }

    /// The transport-level [`Op`] this event predicts for `rank`.
    pub fn to_op(&self, rank: usize) -> Op {
        Op { kind: self.kind(), rank, peer: self.peer(), tag: self.tag() }
    }
}

/// One rank's events for one schedule window: the setup exchange
/// (`epoch: None`) or one training epoch.
#[derive(Clone, Debug)]
pub struct Window {
    pub epoch: Option<u32>,
    pub events: Vec<Event>,
}

/// A full rank schedule: the setup window followed by one window per
/// trained epoch.
#[derive(Clone, Debug)]
pub struct RankSchedule {
    pub rank: usize,
    pub windows: Vec<Window>,
}

impl RankSchedule {
    pub fn n_events(&self) -> usize {
        self.windows.iter().map(|w| w.events.len()).sum()
    }
}

/// The communication schedule of an entire run — every rank, every
/// window — plus the variant bound the staleness check verifies.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub pipelined: bool,
    pub ranks: Vec<RankSchedule>,
}

impl Schedule {
    /// Generate the full schedule for epochs `first_epoch..=last_epoch`
    /// (training epochs are 1-based; `first_epoch > last_epoch` yields
    /// setup-only schedules, the resume-from-final-checkpoint case).
    pub fn generate(
        links: &[RankLinks],
        style: Style,
        pipelined: bool,
        n_layers: usize,
        first_epoch: u32,
        last_epoch: u32,
    ) -> Result<Schedule> {
        let mut ranks = Vec::with_capacity(links.len());
        for lk in links {
            let mut windows = vec![setup_window(lk)];
            for t in first_epoch..=last_epoch {
                windows.push(epoch_window(lk, style, pipelined, n_layers, t)?);
            }
            ranks.push(RankSchedule { rank: lk.rank, windows });
        }
        Ok(Schedule { pipelined, ranks })
    }

    pub fn n_events(&self) -> usize {
        self.ranks.iter().map(|r| r.n_events()).sum()
    }
}

/// One rank's boundary-plan connectivity, the input the generators need
/// from `coordinator::halo`: which peers this rank receives boundary
/// *features* from (`feat_in[j]` ⇔ `halo_ranges[j]` nonempty) and which
/// it sends them to (`feat_out[j]` ⇔ `send_sets[j]` nonempty). Gradient
/// links are the duals: gradients flow back along feature links.
#[derive(Clone, Debug)]
pub struct RankLinks {
    pub rank: usize,
    pub feat_in: Vec<bool>,
    pub feat_out: Vec<bool>,
}

impl RankLinks {
    pub fn new(rank: usize, feat_in: Vec<bool>, feat_out: Vec<bool>) -> RankLinks {
        assert_eq!(feat_in.len(), feat_out.len());
        assert!(rank < feat_in.len());
        assert!(!feat_in[rank] && !feat_out[rank], "rank {rank} linked to itself");
        RankLinks { rank, feat_in, feat_out }
    }

    /// Fully-connected boundary (every pair exchanges features) — what a
    /// connected graph's halo plan typically produces; used by tests.
    pub fn full(n_parts: usize, rank: usize) -> RankLinks {
        let mut feat_in = vec![true; n_parts];
        feat_in[rank] = false;
        RankLinks { rank, feat_in: feat_in.clone(), feat_out: feat_in }
    }

    pub fn n_parts(&self) -> usize {
        self.feat_in.len()
    }

    fn in_peers(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.n_parts()).filter(|&j| j != self.rank && self.feat_in[j])
    }

    fn out_peers(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.n_parts()).filter(|&j| j != self.rank && self.feat_out[j])
    }
}

/// Tag of the one-shot boundary-set exchange (safe: training iterations
/// start at 1, so `iter == 0` setup traffic can never collide).
pub fn setup_tag() -> Tag {
    Tag::new(0, 0, Phase::Setup)
}

/// The boundary-set exchange window: send this rank's halo ids to every
/// feature source, then receive-and-verify from every feature consumer
/// (one blocking receive per peer, in peer order — mirroring
/// `setup_send` / `setup_verify`).
pub fn setup_window(links: &RankLinks) -> Window {
    let mut events = Vec::new();
    for j in links.in_peers() {
        events.push(Event::Send { dst: j, tag: setup_tag() });
    }
    for j in links.out_peers() {
        events.push(Event::PostRecv { src: j, tag: setup_tag() });
        events.push(Event::Wait { src: j, tag: setup_tag(), use_epoch: 0 });
    }
    Window { epoch: None, events }
}

/// The gradient all-reduce segment of epoch `iter` for `rank` of `n`:
/// the standard 2(n−1)-step ring, in the exact order the chosen
/// executor performs it. This is the *single* producer of ring-step
/// tags — both all-reduce executors consume these events.
pub fn ring_events(style: Style, iter: u32, rank: usize, n: usize) -> Result<Vec<Event>> {
    if n <= 1 {
        return Ok(Vec::new());
    }
    let steps = 2 * (n - 1);
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let mut ev = Vec::with_capacity(3 * steps);
    match style {
        Style::Prefetched => {
            for s in 0..steps {
                ev.push(Event::PostRecv { src: prev, tag: step_tag(iter, s, n)? });
            }
            for s in 0..steps {
                let tag = step_tag(iter, s, n)?;
                ev.push(Event::Send { dst: next, tag });
                ev.push(Event::Wait { src: prev, tag, use_epoch: iter });
            }
        }
        Style::Inline => {
            for s in 0..steps {
                let tag = step_tag(iter, s, n)?;
                ev.push(Event::Send { dst: next, tag });
                ev.push(Event::PostRecv { src: prev, tag });
                ev.push(Event::Claim { src: prev, tag, use_epoch: iter });
            }
        }
    }
    Ok(ev)
}

/// One training epoch's events for one rank, in the exact order the
/// `style`'s executor performs them. The staleness encoding is the
/// heart of it: vanilla receives carry `use_epoch == tag.iter`;
/// pipelined boundary receives are claimed for *next* epoch's compute
/// (`use_epoch == tag.iter + 1`) — the paper's one-iteration-stale
/// communication, stated per event.
pub fn epoch_window(
    links: &RankLinks,
    style: Style,
    pipelined: bool,
    n_layers: usize,
    t: u32,
) -> Result<Window> {
    assert!(n_layers >= 1);
    assert!(t >= 1, "training epochs are 1-based (0 is the setup iteration)");
    let n = links.n_parts();
    let rank = links.rank;
    let boundary_use = if pipelined { t + 1 } else { t };
    let feat = |l: usize| Tag::new(t, l as u16, Phase::FwdFeat);
    let grad = |l: usize| Tag::new(t, l as u16, Phase::BwdGrad);
    let mut ev = Vec::new();

    // --- epoch-start receive posts -----------------------------------
    match style {
        Style::Prefetched => {
            for l in 0..n_layers {
                for j in links.in_peers() {
                    ev.push(Event::PostRecv { src: j, tag: feat(l) });
                }
            }
            for l in 1..n_layers {
                for j in links.out_peers() {
                    ev.push(Event::PostRecv { src: j, tag: grad(l) });
                }
            }
        }
        Style::Inline => {
            for l in 0..n_layers {
                for j in links.in_peers() {
                    ev.push(Event::PostRecv { src: j, tag: feat(l) });
                }
                if l > 0 {
                    for j in links.out_peers() {
                        ev.push(Event::PostRecv { src: j, tag: grad(l) });
                    }
                }
            }
        }
    }
    if rank == 0 {
        for j in 1..n {
            ev.push(Event::PostRecv { src: j, tag: Tag::loss(t) });
        }
    }

    // --- forward ------------------------------------------------------
    for l in 0..n_layers {
        for j in links.out_peers() {
            ev.push(Event::Send { dst: j, tag: feat(l) });
        }
        match style {
            // vanilla blocks on this epoch's boundary features; the
            // pipelined variant computes from last epoch's buffers
            Style::Prefetched => {
                if !pipelined {
                    for j in links.in_peers() {
                        ev.push(Event::Wait { src: j, tag: feat(l), use_epoch: t });
                    }
                }
            }
            // the replay claims fresh tensors either way — vanilla uses
            // them now, pipelined banks them for epoch t+1
            Style::Inline => {
                for j in links.in_peers() {
                    ev.push(Event::Claim { src: j, tag: feat(l), use_epoch: boundary_use });
                }
            }
        }
    }

    // --- loss reduction to rank 0 ------------------------------------
    if rank == 0 {
        for j in 1..n {
            match style {
                Style::Prefetched => {
                    ev.push(Event::Wait { src: j, tag: Tag::loss(t), use_epoch: t })
                }
                Style::Inline => ev.push(Event::Claim { src: j, tag: Tag::loss(t), use_epoch: t }),
            }
        }
    } else {
        ev.push(Event::Send { dst: 0, tag: Tag::loss(t) });
    }

    // --- backward -----------------------------------------------------
    for l in (1..n_layers).rev() {
        for j in links.in_peers() {
            ev.push(Event::Send { dst: j, tag: grad(l) });
        }
        match style {
            Style::Prefetched => {
                if !pipelined {
                    for j in links.out_peers() {
                        ev.push(Event::Wait { src: j, tag: grad(l), use_epoch: t });
                    }
                }
            }
            Style::Inline => {
                for j in links.out_peers() {
                    ev.push(Event::Claim { src: j, tag: grad(l), use_epoch: boundary_use });
                }
            }
        }
    }

    // --- pipelined drain (prefetched only): collect this epoch's fresh
    // tensors into the stale buffers epoch t+1 computes from ----------
    if pipelined && style == Style::Prefetched {
        for l in 0..n_layers {
            for j in links.in_peers() {
                ev.push(Event::Wait { src: j, tag: feat(l), use_epoch: t + 1 });
            }
        }
        for l in 1..n_layers {
            for j in links.out_peers() {
                ev.push(Event::Wait { src: j, tag: grad(l), use_epoch: t + 1 });
            }
        }
    }

    // --- model-gradient ring all-reduce ------------------------------
    ev.extend(ring_events(style, t, rank, n)?);

    Ok(Window { epoch: Some(t), events: ev })
}

// ---------------------------------------------------------------------
// Cursor: how executors consume a window
// ---------------------------------------------------------------------

/// Positional reader over one window's events. The executors keep their
/// control flow but take every (peer, tag) from the IR through this —
/// `take_*` returns the contiguous run of matching events at the
/// current position (possibly empty), so a schedule/executor mismatch
/// surfaces as an empty run and a `finish()` failure instead of a
/// silently re-derived tag.
pub struct Cursor<'a> {
    events: &'a [Event],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(events: &'a [Event]) -> Cursor<'a> {
        Cursor { events, pos: 0 }
    }

    fn take_while(&mut self, pred: impl Fn(&Event) -> bool) -> &'a [Event] {
        let start = self.pos;
        while self.pos < self.events.len() && pred(&self.events[self.pos]) {
            self.pos += 1;
        }
        &self.events[start..self.pos]
    }

    /// The leading run of `PostRecv` events (the epoch-start posts).
    pub fn take_posts(&mut self) -> &'a [Event] {
        self.take_while(|e| matches!(e, Event::PostRecv { .. }))
    }

    pub fn take_sends(&mut self, phase: Phase, layer: u16) -> &'a [Event] {
        self.take_while(|e| {
            matches!(e, Event::Send { .. }) && e.tag().phase == phase && e.tag().layer == layer
        })
    }

    pub fn take_waits(&mut self, phase: Phase, layer: u16) -> &'a [Event] {
        self.take_while(|e| {
            matches!(e, Event::Wait { .. }) && e.tag().phase == phase && e.tag().layer == layer
        })
    }

    pub fn take_claims(&mut self, phase: Phase, layer: u16) -> &'a [Event] {
        self.take_while(|e| {
            matches!(e, Event::Claim { .. }) && e.tag().phase == phase && e.tag().layer == layer
        })
    }

    /// The trailing all-reduce segment (every `Phase::Reduce` event).
    pub fn take_ring(&mut self) -> &'a [Event] {
        self.take_while(|e| e.tag().phase == Phase::Reduce)
    }

    /// Take a (`PostRecv`, `Wait`) pair for one blocking receive — the
    /// setup window's receive-and-verify shape — if it is next.
    pub fn take_recv_pair(&mut self, phase: Phase) -> Option<(usize, Tag)> {
        match (self.events.get(self.pos), self.events.get(self.pos + 1)) {
            (Some(&Event::PostRecv { src, tag }), Some(&Event::Wait { src: s2, tag: t2, .. }))
                if tag.phase == phase && s2 == src && t2 == tag =>
            {
                self.pos += 2;
                Some((src, tag))
            }
            _ => None,
        }
    }

    /// Assert the executor consumed the window exactly.
    pub fn finish(self) {
        debug_assert!(
            self.pos == self.events.len(),
            "executor consumed {} of {} scheduled events; next: {:?}",
            self.pos,
            self.events.len(),
            self.events.get(self.pos)
        );
    }
}

// ---------------------------------------------------------------------
// Static analysis
// ---------------------------------------------------------------------

/// What a schedule violation violates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// unmatched send/receive/claim counts on a (src, dst, tag) stream
    Matching,
    /// two live messages on one (src, dst) link share a tag
    Aliasing,
    /// a rank blocks on a message no reachable execution ever sends
    Deadlock,
    /// `use_epoch - tag.iter` breaks the variant's staleness bound
    Staleness,
    /// a receive posted in a window is not claimed in that window
    Hygiene,
}

/// One analyzer finding, locating the exact rank, epoch window, link and
/// tag of the defect.
#[derive(Clone, Debug)]
pub struct Violation {
    pub kind: Kind,
    pub rank: usize,
    pub epoch: Option<u32>,
    pub src: usize,
    pub dst: usize,
    pub tag: Tag,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let epoch = match self.epoch {
            Some(t) => format!("epoch {t}"),
            None => "setup".to_string(),
        };
        write!(
            f,
            "{:?}: rank {} {} ({} -> {}, {:?}): {}",
            self.kind, self.rank, epoch, self.src, self.dst, self.tag, self.detail
        )
    }
}

impl Violation {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("kind", format!("{:?}", self.kind).to_lowercase())
            .set("rank", self.rank)
            .set("src", self.src)
            .set("dst", self.dst)
            .set("iter", self.tag.iter)
            .set("layer", self.tag.layer as usize)
            .set("phase", format!("{:?}", self.tag.phase))
            .set("detail", self.detail.as_str());
        if let Some(t) = self.epoch {
            j = j.set("epoch", t);
        }
        j
    }
}

#[derive(Default)]
struct LinkState {
    sent: u64,
    posted: u64,
    claimed: u64,
}

/// Statically verify a schedule. Runs a greedy cross-rank simulation
/// (sound and complete for deadlock: enabling is monotone) tracking
/// per-(src, dst, tag) send/post/claim counts, then checks end-state
/// matching and per-window handle hygiene. Returns every violation
/// found; an empty vector is the proof.
pub fn verify(sched: &Schedule) -> Vec<Violation> {
    let n = sched.ranks.len();
    let mut out: Vec<Violation> = Vec::new();
    // flatten each rank's windows into one stream, remembering epochs
    let streams: Vec<Vec<(Option<u32>, Event)>> = sched
        .ranks
        .iter()
        .map(|r| {
            r.windows.iter().flat_map(|w| w.events.iter().map(|&e| (w.epoch, e))).collect()
        })
        .collect();
    let mut pos = vec![0usize; n];
    let mut links: HashMap<(usize, usize, Tag), LinkState> = HashMap::new();

    let staleness = |out: &mut Vec<Violation>,
                     rank: usize,
                     epoch: Option<u32>,
                     src: usize,
                     tag: Tag,
                     use_epoch: u32| {
        if tag.phase != Phase::FwdFeat && tag.phase != Phase::BwdGrad {
            return; // ring / loss / setup traffic has no staleness bound
        }
        let want: i64 = if sched.pipelined { 1 } else { 0 };
        let got = use_epoch as i64 - tag.iter as i64;
        if got != want {
            out.push(Violation {
                kind: Kind::Staleness,
                rank,
                epoch,
                src,
                dst: rank,
                tag,
                detail: format!(
                    "payload produced at iteration {} consumed by epoch {use_epoch} \
                     ({got} epochs stale; the {} variant requires exactly {want})",
                    tag.iter,
                    if sched.pipelined { "pipelined" } else { "vanilla" }
                ),
            });
        }
    };

    loop {
        let mut progressed = false;
        for (r, stream) in streams.iter().enumerate() {
            while let Some(&(epoch, ev)) = stream.get(pos[r]) {
                match ev {
                    Event::PostRecv { src, tag } => {
                        let l = links.entry((src, r, tag)).or_default();
                        l.posted += 1;
                        if l.posted - l.claimed > 1 {
                            out.push(Violation {
                                kind: Kind::Aliasing,
                                rank: r,
                                epoch,
                                src,
                                dst: r,
                                tag,
                                detail: format!(
                                    "{} receives posted on this link share the tag while \
                                     outstanding — payloads would be indistinguishable",
                                    l.posted - l.claimed
                                ),
                            });
                        }
                    }
                    Event::Send { dst, tag } => {
                        let l = links.entry((r, dst, tag)).or_default();
                        l.sent += 1;
                        if l.sent - l.claimed > 1 {
                            out.push(Violation {
                                kind: Kind::Aliasing,
                                rank: r,
                                epoch,
                                src: r,
                                dst,
                                tag,
                                detail: format!(
                                    "{} messages live on this link share the tag — the \
                                     consumer cannot tell them apart",
                                    l.sent - l.claimed
                                ),
                            });
                        }
                    }
                    Event::Wait { src, tag, use_epoch } | Event::Claim { src, tag, use_epoch } => {
                        let l = links.entry((src, r, tag)).or_default();
                        if l.posted <= l.claimed {
                            // double claim / claim with no posted receive:
                            // report, then consume a message if one exists
                            // so one defect doesn't cascade into a fake
                            // deadlock of the whole schedule
                            out.push(Violation {
                                kind: Kind::Matching,
                                rank: r,
                                epoch,
                                src,
                                dst: r,
                                tag,
                                detail: "claim without an outstanding posted receive \
                                         (double claim, or the post is missing)"
                                    .to_string(),
                            });
                            l.posted += 1;
                        }
                        if l.sent > l.claimed {
                            l.claimed += 1;
                            staleness(&mut out, r, epoch, src, tag, use_epoch);
                        } else {
                            break; // blocked until the peer sends
                        }
                    }
                }
                pos[r] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    // deadlock: any rank stuck mid-stream after the fixpoint
    for (r, stream) in streams.iter().enumerate() {
        if let Some(&(epoch, ev)) = stream.get(pos[r]) {
            out.push(Violation {
                kind: Kind::Deadlock,
                rank: r,
                epoch,
                src: ev.peer(),
                dst: r,
                tag: ev.tag(),
                detail: format!(
                    "rank blocks here forever ({} of its events unreached); no \
                     execution delivers this message",
                    stream.len() - pos[r]
                ),
            });
        }
    }

    // end-state matching: counters must balance on every stream
    let mut leftovers: Vec<(&(usize, usize, Tag), &LinkState)> =
        links.iter().filter(|(_, l)| l.sent != l.claimed || l.posted != l.claimed).collect();
    leftovers.sort_by_key(|((s, d, tag), _)| {
        (*s, *d, tag.iter, tag.layer, tag.phase.code())
    });
    for (&(src, dst, tag), l) in leftovers {
        if l.sent > l.claimed {
            out.push(Violation {
                kind: Kind::Matching,
                rank: dst,
                epoch: None,
                src,
                dst,
                tag,
                detail: format!(
                    "{} orphan send(s): sent {}, claimed {}",
                    l.sent - l.claimed,
                    l.sent,
                    l.claimed
                ),
            });
        }
        if l.posted > l.claimed {
            out.push(Violation {
                kind: Kind::Matching,
                rank: dst,
                epoch: None,
                src,
                dst,
                tag,
                detail: format!(
                    "posted receive(s) never claimed: posted {}, claimed {}",
                    l.posted, l.claimed
                ),
            });
        }
    }

    // handle hygiene: within each window, posts and claims must pair up
    // (the engines assert their posted-handle maps drain every epoch)
    for (r, rs) in sched.ranks.iter().enumerate() {
        for w in &rs.windows {
            let mut open: HashMap<(usize, Tag), i64> = HashMap::new();
            for ev in &w.events {
                match *ev {
                    Event::PostRecv { src, tag } => *open.entry((src, tag)).or_default() += 1,
                    Event::Wait { src, tag, .. } | Event::Claim { src, tag, .. } => {
                        *open.entry((src, tag)).or_default() -= 1
                    }
                    Event::Send { .. } => {}
                }
            }
            let mut dangling: Vec<((usize, Tag), i64)> =
                open.into_iter().filter(|&(_, c)| c != 0).collect();
            dangling.sort_by_key(|((s, tag), _)| (*s, tag.iter, tag.layer, tag.phase.code()));
            for ((src, tag), c) in dangling {
                out.push(Violation {
                    kind: Kind::Hygiene,
                    rank: r,
                    epoch: w.epoch,
                    src,
                    dst: r,
                    tag,
                    detail: if c > 0 {
                        format!("{c} receive(s) posted in this window but not claimed in it")
                    } else {
                        format!("{} claim(s) in this window with no post in it", -c)
                    },
                });
            }
        }
    }

    out
}

// ---------------------------------------------------------------------
// Runtime observation (conformance mode / property tests)
// ---------------------------------------------------------------------

/// Kind of a live transport operation, mirroring [`Event`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    PostRecv,
    Send,
    Wait,
    Claim,
}

/// One live transport operation: `rank` is the acting rank (the sender
/// for `Send`, the receiver otherwise), `peer` the other endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Op {
    pub kind: OpKind,
    pub rank: usize,
    pub peer: usize,
    pub tag: Tag,
}

/// Receiver of live transport operations (installed with [`set_sink`]).
pub trait EventSink: Send {
    fn record(&self, op: Op);
}

static SINK_ON: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Box<dyn EventSink>>> = Mutex::new(None);

/// Report a live transport operation to the installed sink, if any.
/// The disabled path is one relaxed atomic load — transports call this
/// on every operation.
pub(crate) fn observe(kind: OpKind, rank: usize, peer: usize, tag: Tag) {
    if !SINK_ON.load(Ordering::Relaxed) {
        return;
    }
    if let Some(s) = SINK.lock().unwrap().as_ref() {
        s.record(Op { kind, rank, peer, tag });
    }
}

/// Install a process-global sink observing every transport operation.
pub fn set_sink(sink: Box<dyn EventSink>) {
    let mut g = SINK.lock().unwrap();
    *g = Some(sink);
    SINK_ON.store(true, Ordering::Release);
}

/// Remove and return the installed sink.
pub fn clear_sink() -> Option<Box<dyn EventSink>> {
    let mut g = SINK.lock().unwrap();
    SINK_ON.store(false, Ordering::Release);
    g.take()
}

/// Is conformance checking requested for this process? Debug builds
/// only (the hooks stay, the sink is never installed in release), and
/// opt-in via `PIPEGCN_CONFORMANCE=1`.
pub fn conformance_requested() -> bool {
    cfg!(debug_assertions)
        && std::env::var("PIPEGCN_CONFORMANCE").map(|v| v == "1").unwrap_or(false)
}

/// Sink that appends every op to a shared vector (property tests).
#[derive(Clone, Default)]
pub struct Recorder {
    ops: Arc<Mutex<Vec<Op>>>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Everything recorded so far, in global arrival order.
    pub fn snapshot(&self) -> Vec<Op> {
        self.ops.lock().unwrap().clone()
    }

    /// One rank's op stream (per-rank order is the conformance contract;
    /// cross-rank interleaving is scheduler timing).
    pub fn by_rank(&self, rank: usize) -> Vec<Op> {
        self.ops.lock().unwrap().iter().filter(|o| o.rank == rank).copied().collect()
    }
}

impl EventSink for Recorder {
    fn record(&self, op: Op) {
        self.ops.lock().unwrap().push(op);
    }
}

/// Sink that checks a live engine against a generated [`Schedule`]:
/// each rank's operations must be exactly its IR events, in order.
/// Panics with the full diagnostic at the first divergence. Trace
/// clock-sync / span-ship sentinel traffic (`Phase::Setup` at the
/// reserved top iteration values) is observability-only and ignored.
pub struct Conformance {
    expected: Mutex<Vec<VecDeque<Op>>>,
}

impl Conformance {
    pub fn new(sched: &Schedule) -> Conformance {
        let expected = sched
            .ranks
            .iter()
            .map(|r| {
                r.windows
                    .iter()
                    .flat_map(|w| w.events.iter().map(|e| e.to_op(r.rank)))
                    .collect()
            })
            .collect();
        Conformance { expected: Mutex::new(expected) }
    }

    /// For a single-rank process (TCP worker): keep only `rank`'s stream.
    pub fn for_rank(sched: &Schedule, rank: usize) -> Conformance {
        let c = Conformance::new(sched);
        {
            let mut g = c.expected.lock().unwrap();
            for (r, q) in g.iter_mut().enumerate() {
                if r != rank {
                    q.clear();
                }
            }
        }
        c
    }
}

impl EventSink for Conformance {
    fn record(&self, op: Op) {
        if op.tag.phase == Phase::Setup && op.tag.iter >= crate::obs::trace::SHIP_ITER {
            return; // tracing sentinels, not schedule traffic
        }
        let mut g = self.expected.lock().unwrap();
        let q = match g.get_mut(op.rank) {
            Some(q) => q,
            None => panic!("schedule conformance: op from unscheduled rank: {op:?}"),
        };
        match q.pop_front() {
            Some(want) if want == op => {}
            Some(want) => panic!(
                "schedule conformance violated: rank {} was scheduled to {:?} but performed {:?}",
                op.rank, want, op
            ),
            None => panic!(
                "schedule conformance violated: rank {} performed {:?} past the end of its schedule",
                op.rank, op
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_links(n: usize) -> Vec<RankLinks> {
        (0..n).map(|r| RankLinks::full(n, r)).collect()
    }

    fn kinds(vs: &[Violation]) -> Vec<Kind> {
        vs.iter().map(|v| v.kind).collect()
    }

    #[test]
    fn valid_schedules_verify_clean() {
        for style in [Style::Prefetched, Style::Inline] {
            for pipelined in [false, true] {
                for parts in 1..=4 {
                    for n_layers in [1, 2, 3] {
                        let links = full_links(parts);
                        let s =
                            Schedule::generate(&links, style, pipelined, n_layers, 1, 3).unwrap();
                        let vs = verify(&s);
                        assert!(
                            vs.is_empty(),
                            "{style:?} pipelined={pipelined} parts={parts} layers={n_layers}: {:?}",
                            vs.iter().map(|v| v.to_string()).collect::<Vec<_>>()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sparse_asymmetric_links_verify_clean() {
        // rank 0 feeds 1 and 2; only 1 feeds back; duals must line up
        let links = vec![
            RankLinks::new(0, vec![false, true, false], vec![false, true, true]),
            RankLinks::new(1, vec![true, false, false], vec![true, false, false]),
            RankLinks::new(2, vec![true, false, false], vec![false, false, false]),
        ];
        for style in [Style::Prefetched, Style::Inline] {
            for pipelined in [false, true] {
                let s = Schedule::generate(&links, style, pipelined, 2, 1, 2).unwrap();
                let vs = verify(&s);
                assert!(vs.is_empty(), "{style:?}: {:?}", kinds(&vs));
            }
        }
    }

    /// The corrupted-schedule acceptance case: a pipelined claim whose
    /// use-epoch is off by one must be rejected, and the diagnostic must
    /// name the rank, epoch, link and tag.
    #[test]
    fn off_by_one_staleness_rejected_with_diagnostic() {
        let links = full_links(2);
        let mut s = Schedule::generate(&links, Style::Inline, true, 2, 1, 2).unwrap();
        let ev = s.ranks[1].windows[1]
            .events
            .iter_mut()
            .find(|e| matches!(e, Event::Claim { tag, .. } if tag.phase == Phase::FwdFeat))
            .unwrap();
        if let Event::Claim { use_epoch, .. } = ev {
            *use_epoch += 1; // 2 epochs stale instead of the paper's 1
        }
        let vs = verify(&s);
        let v = vs.iter().find(|v| v.kind == Kind::Staleness).expect("staleness violation");
        assert_eq!((v.rank, v.epoch, v.src, v.dst), (1, Some(1), 0, 1));
        assert_eq!(v.tag, Tag::new(1, 0, Phase::FwdFeat));
        let msg = v.to_string();
        for needle in ["rank 1", "epoch 1", "0 -> 1", "FwdFeat", "2 epochs stale"] {
            assert!(msg.contains(needle), "missing {needle:?} in {msg}");
        }
        let row = v.to_json().to_compact();
        assert!(row.contains("\"kind\":\"staleness\""), "{row}");
    }

    /// The other acceptance corruption: two live messages on one link
    /// sharing a tag (the layer-1 feature send re-tagged as layer 0).
    #[test]
    fn aliased_tag_rejected_with_diagnostic() {
        let links = full_links(2);
        let mut s = Schedule::generate(&links, Style::Prefetched, true, 2, 1, 1).unwrap();
        let alias = Tag::new(1, 0, Phase::FwdFeat);
        let ev = s.ranks[0].windows[1]
            .events
            .iter_mut()
            .find(|e| {
                matches!(e, Event::Send { tag, .. } if *tag == Tag::new(1, 1, Phase::FwdFeat))
            })
            .unwrap();
        if let Event::Send { tag, .. } = ev {
            *tag = alias;
        }
        let vs = verify(&s);
        let v = vs.iter().find(|v| v.kind == Kind::Aliasing).expect("aliasing violation");
        assert_eq!((v.rank, v.epoch, v.src, v.dst, v.tag), (0, Some(1), 0, 1, alias));
        let msg = v.to_string();
        for needle in ["rank 0", "epoch 1", "0 -> 1", "share the tag"] {
            assert!(msg.contains(needle), "missing {needle:?} in {msg}");
        }
        // the starved original tag is also caught downstream
        assert!(kinds(&vs).contains(&Kind::Deadlock), "{:?}", kinds(&vs));
    }

    #[test]
    fn missing_send_is_deadlock_and_unmatched() {
        let links = full_links(3);
        let mut s = Schedule::generate(&links, Style::Prefetched, false, 2, 1, 1).unwrap();
        let w = &mut s.ranks[2].windows[1];
        let i = w
            .events
            .iter()
            .position(|e| matches!(e, Event::Send { tag, .. } if tag.phase == Phase::FwdFeat))
            .unwrap();
        w.events.remove(i);
        let vs = verify(&s);
        let ks = kinds(&vs);
        assert!(ks.contains(&Kind::Deadlock), "{ks:?}");
        assert!(ks.contains(&Kind::Matching), "{ks:?}");
    }

    #[test]
    fn double_claim_is_matching_violation() {
        let links = full_links(2);
        let mut s = Schedule::generate(&links, Style::Inline, false, 2, 1, 1).unwrap();
        let w = &mut s.ranks[1].windows[1];
        let i = w.events.iter().position(|e| matches!(e, Event::Claim { .. })).unwrap();
        let dup = w.events[i];
        w.events.insert(i + 1, dup);
        let vs = verify(&s);
        assert!(kinds(&vs).contains(&Kind::Matching), "{:?}", kinds(&vs));
    }

    #[test]
    fn unclaimed_post_is_hygiene_violation() {
        let links = full_links(2);
        let mut s = Schedule::generate(&links, Style::Prefetched, true, 2, 1, 1).unwrap();
        let w = &mut s.ranks[0].windows[1];
        // drop a drain wait: the posted handle is left dangling
        let i = w.events.iter().rposition(|e| matches!(e, Event::Wait { .. })).unwrap();
        w.events.remove(i);
        let vs = verify(&s);
        let ks = kinds(&vs);
        assert!(ks.contains(&Kind::Hygiene), "{ks:?}");
        assert!(ks.contains(&Kind::Matching), "{ks:?}");
    }

    #[test]
    fn ring_events_reject_unrepresentable_rank_count() {
        let err = ring_events(Style::Inline, 0, 0, 40_000).unwrap_err().to_string();
        assert!(err.contains("cannot fit"), "{err}");
        assert!(err.contains("40000"), "{err}");
    }

    #[test]
    fn setup_only_schedule_for_zero_epochs() {
        let links = full_links(2);
        // first_epoch > last_epoch: resume-at-final-checkpoint shape
        let s = Schedule::generate(&links, Style::Prefetched, true, 2, 4, 3).unwrap();
        assert_eq!(s.ranks[0].windows.len(), 1);
        assert!(verify(&s).is_empty());
    }

    #[test]
    fn cursor_consumes_windows_exactly() {
        let links = full_links(3);
        let w = epoch_window(&links[1], Style::Prefetched, false, 2, 5).unwrap();
        let mut cur = Cursor::new(&w.events);
        let posts = cur.take_posts();
        assert!(posts.iter().all(|e| matches!(e, Event::PostRecv { .. })));
        // 2 peers × (2 fwd layers + 1 bwd layer) — no loss posts off rank 0
        assert_eq!(posts.len(), 6);
        for l in 0..2u16 {
            assert_eq!(cur.take_sends(Phase::FwdFeat, l).len(), 2);
            assert_eq!(cur.take_waits(Phase::FwdFeat, l).len(), 2);
        }
        assert_eq!(cur.take_sends(Phase::Loss, 0).len(), 1);
        assert_eq!(cur.take_sends(Phase::BwdGrad, 1).len(), 2);
        assert_eq!(cur.take_waits(Phase::BwdGrad, 1).len(), 2);
        // 3 ranks → 4 ring steps, prefetched: 4 posts + 4 (send, wait)
        assert_eq!(cur.take_ring().len(), 12);
        cur.finish();
    }

    #[test]
    fn recorder_sink_captures_fabric_traffic() {
        use crate::comm::Fabric;
        let rec = Recorder::new();
        set_sink(Box::new(rec.clone()));
        let f = Fabric::new(2);
        // lib tests share the process-global sink: other tests' fabric
        // traffic may interleave, so select this test's ops by a tag
        // iteration nothing else uses
        let tag = Tag::new(0xDEAD_BEEF, 0, Phase::FwdFeat);
        f.send(0, 1, tag, vec![1.0]);
        let _ = f.recv_now(0, 1, tag);
        clear_sink();
        f.send(0, 1, tag, vec![2.0]); // not recorded: sink removed
        let ops: Vec<Op> =
            rec.snapshot().into_iter().filter(|o| o.tag == tag).collect();
        assert_eq!(
            ops,
            vec![
                Op { kind: OpKind::Send, rank: 0, peer: 1, tag },
                Op { kind: OpKind::PostRecv, rank: 1, peer: 0, tag },
                Op { kind: OpKind::Claim, rank: 1, peer: 0, tag },
            ]
        );
    }
}
