//! Live metrics exposition: a tiny HTTP/1.1 GET handler that renders
//! the global [`super::Registry`] in the Prometheus text format
//! (`curl http://HOST:PORT/metrics` — any path answers the same).
//!
//! Std-only, one background accept thread, non-blocking accept poll so
//! shutdown is prompt. Started by `train` / `launch` workers / `serve`
//! when `--metrics-addr` is given; binding port 0 picks an ephemeral
//! port (reported by [`MetricsServer::addr`]).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::util::error::{Context, Result};

/// Accept-poll interval while idle.
const POLL: Duration = Duration::from_millis(25);
/// Per-request read timeout and request-size cap.
const READ_TIMEOUT: Duration = Duration::from_secs(1);
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Handle to a running exposition endpoint; dropping it stops the
/// accept thread and releases the port.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// The bound address (useful with `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Bind `addr` and serve the global registry until the returned handle
/// is dropped.
pub fn serve(addr: &str) -> Result<MetricsServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let bound = listener.local_addr().context("metrics endpoint local_addr")?;
    listener
        .set_nonblocking(true)
        .context("metrics endpoint set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = thread::Builder::new()
        .name("obs-metrics".to_string())
        .spawn(move || accept_loop(listener, stop2))
        .context("spawning metrics accept thread")?;
    Ok(MetricsServer { addr: bound, stop, handle: Some(handle) })
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // served inline: scrapes are rare and tiny, and inline
                // handling keeps the thread count flat
                let _ = handle_request(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(POLL),
            Err(_) => thread::sleep(POLL),
        }
    }
}

/// Read the request head (discarded beyond sanity limits), then answer
/// with the current exposition text. Peak RSS is sampled per scrape so
/// the gauge is fresh without a background sampler.
fn handle_request(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    stream.set_nodelay(true).ok();
    let mut buf = [0u8; 1024];
    let mut head: Vec<u8> = Vec::new();
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break, // timeout or reset: answer with what we have
        };
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() >= MAX_REQUEST_BYTES {
            break;
        }
    }
    let reg = super::global();
    super::sample_peak_rss(&reg);
    let body = reg.render();
    let response = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn endpoint_serves_prometheus_text() {
        crate::obs::global()
            .counter("http_test_total", &[("case", "endpoint")])
            .add(3.0);
        let server = serve("127.0.0.1:0").expect("bind ephemeral");
        let text = scrape(server.addr());
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("text/plain"), "{text}");
        assert!(
            text.contains("pipegcn_http_test_total{case=\"endpoint\"} 3"),
            "{text}"
        );
        // a second scrape works (connection-per-request)
        let again = scrape(server.addr());
        assert!(again.contains("pipegcn_http_test_total"), "{again}");
    }

    #[test]
    fn drop_releases_port() {
        let server = serve("127.0.0.1:0").unwrap();
        let addr = server.addr();
        drop(server);
        // port must be rebindable promptly after drop
        let rebound = TcpListener::bind(addr);
        assert!(rebound.is_ok());
    }
}
