//! Span tracer: bounded per-process ring buffer of (rank, layer, phase,
//! t_start, t_end) events, merged across ranks into Chrome trace-event
//! JSON (`chrome://tracing` / Perfetto compatible).
//!
//! The tracer is off by default; when off, the only hot-path cost is
//! one relaxed atomic load in [`enabled`] (and [`now_us`] returns 0
//! without touching the clock). Cross-process alignment uses an
//! NTP-style offset estimated against rank 0 right after the rendezvous
//! handshake ([`clock_sync_offset`] / [`serve_clock_sync`]); at
//! shutdown workers ship their buffers to rank 0 over the existing
//! frame protocol ([`ship_spans`] / [`collect_spans`]) using sentinel
//! `Phase::Setup` tags whose iter values sit at the top of the `u32`
//! range, far above any real epoch counter. All sync/ship traffic only
//! happens when tracing is enabled, so untraced runs move exactly the
//! bytes they always did.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::comm::{self, Tag, Transport};
use crate::util::error::Result;
use crate::util::json::Json;

/// Ring-buffer capacity per process; oldest spans drop first.
pub const SPAN_CAP: usize = 1 << 16;

/// Sentinel iter for clock-sync ping frames (worker → rank 0).
pub const SYNC_PING_ITER: u32 = u32::MAX;
/// Sentinel iter for clock-sync pong frames (rank 0 → worker).
pub const SYNC_PONG_ITER: u32 = u32::MAX - 1;
/// Sentinel iter for the end-of-run span shipment (worker → rank 0).
pub const SHIP_ITER: u32 = u32::MAX - 2;
/// Ping/pong rounds per worker; the minimum-RTT round wins.
pub const SYNC_ROUNDS: usize = 5;

/// What a span measures; determines its Chrome-trace lane (`tid`) and
/// category so compute and comm rows sit apart and overlap is visible.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    /// One layer's forward kernel on one partition.
    FwdLayer,
    /// One layer's backward kernel on one partition.
    BwdLayer,
    /// A receive-handle wait that may park (comm lane).
    CommWait,
    /// Ring-allreduce of the loss metrics (comm lane).
    Reduce,
    /// End-of-epoch drain of stale in-flight messages.
    Drain,
    /// Whole-epoch envelope span.
    Epoch,
    /// Loss/eval computation.
    Loss,
}

impl Kind {
    pub fn code(self) -> u32 {
        match self {
            Kind::FwdLayer => 0,
            Kind::BwdLayer => 1,
            Kind::CommWait => 2,
            Kind::Reduce => 3,
            Kind::Drain => 4,
            Kind::Epoch => 5,
            Kind::Loss => 6,
        }
    }

    pub fn from_code(c: u32) -> Option<Kind> {
        Some(match c {
            0 => Kind::FwdLayer,
            1 => Kind::BwdLayer,
            2 => Kind::CommWait,
            3 => Kind::Reduce,
            4 => Kind::Drain,
            5 => Kind::Epoch,
            6 => Kind::Loss,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Kind::FwdLayer => "fwd",
            Kind::BwdLayer => "bwd",
            Kind::CommWait => "comm_wait",
            Kind::Reduce => "reduce",
            Kind::Drain => "drain",
            Kind::Epoch => "epoch",
            Kind::Loss => "loss",
        }
    }

    /// Chrome-trace thread lane within a rank's process row.
    pub fn lane(self) -> u32 {
        match self {
            Kind::FwdLayer | Kind::BwdLayer | Kind::Drain | Kind::Loss => 0,
            Kind::CommWait | Kind::Reduce => 1,
            Kind::Epoch => 2,
        }
    }

    pub fn category(self) -> &'static str {
        match self.lane() {
            0 => "compute",
            1 => "comm",
            _ => "epoch",
        }
    }
}

/// One recorded interval on one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub rank: u32,
    pub layer: u32,
    pub epoch: u32,
    pub kind: Kind,
    pub start_us: u64,
    pub end_us: u64,
}

struct TraceState {
    base: Instant,
    /// Added to every span's timestamps at [`take`] so worker clocks
    /// line up with rank 0's.
    offset_us: i64,
    spans: VecDeque<Span>,
    dropped: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<TraceState>> = Mutex::new(None);

/// Turn the tracer on for this process (idempotent; the monotonic base
/// is captured on the first call).
pub fn enable() {
    let mut g = STATE.lock().unwrap();
    if g.is_none() {
        *g = Some(TraceState {
            base: Instant::now(),
            offset_us: 0,
            spans: VecDeque::new(),
            dropped: 0,
        });
    }
    ENABLED.store(true, Ordering::Release);
}

/// Whether spans are being recorded — the one check on hot paths.
///
/// Ordering audit: the `Relaxed` load is sound because `enabled()` is
/// only a *gate* — every actual access to trace state re-takes the
/// `STATE` mutex, which provides the acquire/release edge to the data
/// `enable()` initialized. A stale `false` merely skips recording one
/// span at startup; a `true` can never outrun the state it guards.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since this process's trace base (0 when disabled, so
/// callers can grab a start stamp unconditionally).
pub fn now_us() -> u64 {
    if !enabled() {
        return 0;
    }
    let g = STATE.lock().unwrap();
    match &*g {
        Some(st) => st.base.elapsed().as_micros() as u64,
        None => 0,
    }
}

/// Record a span that started at `start_us` (from [`now_us`]) and ends
/// now. No-op when disabled.
pub fn span(rank: usize, kind: Kind, layer: usize, epoch: usize, start_us: u64) {
    if !enabled() {
        return;
    }
    let mut g = STATE.lock().unwrap();
    if let Some(st) = &mut *g {
        let end_us = st.base.elapsed().as_micros() as u64;
        if st.spans.len() >= SPAN_CAP {
            st.spans.pop_front();
            st.dropped += 1;
        }
        st.spans.push_back(Span {
            rank: rank as u32,
            layer: layer as u32,
            epoch: epoch as u32,
            kind,
            start_us,
            end_us: end_us.max(start_us),
        });
    }
}

/// Set this process's clock offset relative to rank 0 (applied when the
/// buffer is drained, so spans recorded before sync still align).
pub fn set_offset_us(offset: i64) {
    let mut g = STATE.lock().unwrap();
    if let Some(st) = &mut *g {
        st.offset_us = offset;
    }
}

/// Drain the buffer, with the clock offset applied. Count of spans
/// dropped to the ring cap is returned alongside.
pub fn take() -> (Vec<Span>, u64) {
    let mut g = STATE.lock().unwrap();
    match &mut *g {
        Some(st) => {
            let off = st.offset_us;
            let dropped = st.dropped;
            st.dropped = 0;
            let spans = st
                .spans
                .drain(..)
                .map(|mut s| {
                    s.start_us = (s.start_us as i64 + off).max(0) as u64;
                    s.end_us = (s.end_us as i64 + off).max(0) as u64;
                    s
                })
                .collect();
            (spans, dropped)
        }
        None => (Vec::new(), 0),
    }
}

// ---------------------------------------------------------------------
// Wire encoding (shipped via comm::encode_u32s over the frame protocol)
// ---------------------------------------------------------------------

const SPAN_WORDS: usize = 8;

/// Pack spans as `[n, then 8 u32 words per span]` for transit through
/// the f32 payload channel (bit-exact both ways).
pub fn encode_spans(spans: &[Span]) -> Vec<u32> {
    let mut out = Vec::with_capacity(1 + spans.len() * SPAN_WORDS);
    out.push(spans.len() as u32);
    for s in spans {
        out.push(s.rank);
        out.push(s.layer);
        out.push(s.epoch);
        out.push(s.kind.code());
        out.push(s.start_us as u32);
        out.push((s.start_us >> 32) as u32);
        out.push(s.end_us as u32);
        out.push((s.end_us >> 32) as u32);
    }
    out
}

pub fn decode_spans(words: &[u32]) -> Result<Vec<Span>> {
    if words.is_empty() {
        crate::bail!("span payload empty");
    }
    let n = words[0] as usize;
    if words.len() != 1 + n * SPAN_WORDS {
        crate::bail!(
            "span payload length mismatch: header says {} spans, got {} words",
            n,
            words.len() - 1
        );
    }
    let mut spans = Vec::with_capacity(n);
    for c in words[1..].chunks_exact(SPAN_WORDS) {
        let kind = match Kind::from_code(c[3]) {
            Some(k) => k,
            None => crate::bail!("unknown span kind code {}", c[3]),
        };
        spans.push(Span {
            rank: c[0],
            layer: c[1],
            epoch: c[2],
            kind,
            start_us: (c[4] as u64) | ((c[5] as u64) << 32),
            end_us: (c[6] as u64) | ((c[7] as u64) << 32),
        });
    }
    Ok(spans)
}

// ---------------------------------------------------------------------
// Cross-rank clock sync + span shipping
// ---------------------------------------------------------------------

fn sync_tag(iter: u32, rank: usize) -> Tag {
    Tag::new(iter, rank as u16, comm::Phase::Setup)
}

/// Rank 0 side of the clock handshake: answer [`SYNC_ROUNDS`] pings
/// from every other rank with rank 0's current trace clock. Workers are
/// served sequentially; their frames queue in the inbox, and min-RTT
/// selection on the worker side absorbs the wait.
pub fn serve_clock_sync(t: &dyn Transport, n: usize) {
    for src in 1..n {
        for _ in 0..SYNC_ROUNDS {
            let _ = t.recv_blocking(src, 0, sync_tag(SYNC_PING_ITER, src));
            let pong = comm::encode_u32s(&[now_us() as u32, (now_us() >> 32) as u32]);
            t.send(0, src, sync_tag(SYNC_PONG_ITER, src), pong);
        }
    }
}

/// Worker side of the clock handshake: estimate this process's trace
/// clock offset relative to rank 0 via [`SYNC_ROUNDS`] ping/pongs,
/// keeping the minimum-RTT round (offset = t1 − (t0 + t2)/2).
pub fn clock_sync_offset(t: &dyn Transport, rank: usize) -> i64 {
    let mut best_rtt = u64::MAX;
    let mut best_offset = 0i64;
    for _ in 0..SYNC_ROUNDS {
        let t0 = now_us();
        t.send(rank, 0, sync_tag(SYNC_PING_ITER, rank), Vec::new());
        let pong = t.recv_blocking(0, rank, sync_tag(SYNC_PONG_ITER, rank));
        let t2 = now_us();
        let words = comm::decode_u32s(&pong);
        if words.len() != 2 {
            continue;
        }
        let t1 = (words[0] as u64) | ((words[1] as u64) << 32);
        let rtt = t2.saturating_sub(t0);
        if rtt < best_rtt {
            best_rtt = rtt;
            best_offset = t1 as i64 - ((t0 + t2) / 2) as i64;
        }
    }
    best_offset
}

/// Ship this rank's (offset-aligned) span buffer to rank 0.
pub fn ship_spans(t: &dyn Transport, rank: usize) {
    let (spans, _dropped) = take();
    let words = encode_spans(&spans);
    t.send(rank, 0, sync_tag(SHIP_ITER, rank), comm::encode_u32s(&words));
}

/// Rank 0: merge its own buffer with every worker's shipment, sorted by
/// start time. Undecodable shipments are skipped (the trace file is a
/// diagnostic, not a correctness artifact).
pub fn collect_spans(t: &dyn Transport, n: usize) -> Vec<Span> {
    let (mut spans, _dropped) = take();
    for src in 1..n {
        let payload = t.recv_blocking(src, 0, sync_tag(SHIP_ITER, src));
        if let Ok(theirs) = decode_spans(&comm::decode_u32s(&payload)) {
            spans.extend(theirs);
        }
    }
    spans.sort_by_key(|s| (s.start_us, s.rank, s.kind.code()));
    spans
}

// ---------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------

/// Render spans as a Chrome trace-event document: complete ("X") events
/// with `pid` = rank and `tid` = lane (0 compute, 1 comm, 2 epoch), all
/// timestamps in microseconds on rank 0's clock.
pub fn chrome_trace_json(spans: &[Span]) -> Json {
    let mut events = Vec::with_capacity(spans.len());
    for s in spans {
        let name = match s.kind {
            Kind::FwdLayer | Kind::BwdLayer | Kind::CommWait => {
                format!("{}_l{}", s.kind.name(), s.layer)
            }
            _ => s.kind.name().to_string(),
        };
        events.push(
            Json::obj()
                .set("name", name)
                .set("cat", s.kind.category())
                .set("ph", "X")
                .set("ts", s.start_us as f64)
                .set("dur", (s.end_us - s.start_us) as f64)
                .set("pid", s.rank as f64)
                .set("tid", s.kind.lane() as f64)
                .set(
                    "args",
                    Json::obj()
                        .set("epoch", s.epoch as f64)
                        .set("layer", s.layer as f64),
                ),
        );
    }
    Json::obj().set("traceEvents", Json::Arr(events))
}

/// Write the merged trace to `path` (parent directories created).
pub fn write_chrome_trace(path: &str, spans: &[Span]) -> Result<()> {
    use crate::util::error::Context;
    let p = std::path::Path::new(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {}", dir.display()))?;
        }
    }
    std::fs::write(p, chrome_trace_json(spans).to_compact())
        .with_context(|| format!("writing trace file {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Span> {
        vec![
            Span {
                rank: 0,
                layer: 0,
                epoch: 1,
                kind: Kind::FwdLayer,
                start_us: 10,
                end_us: 35,
            },
            Span {
                rank: 1,
                layer: 2,
                epoch: 1,
                kind: Kind::CommWait,
                start_us: 12,
                end_us: 1 + (7u64 << 32),
            },
        ]
    }

    #[test]
    fn spans_roundtrip_through_wire_encoding() {
        let spans = sample();
        let words = encode_spans(&spans);
        assert_eq!(decode_spans(&words).unwrap(), spans);
        // and through the f32 payload channel, bit-exactly
        let payload = comm::encode_u32s(&words);
        let back = comm::decode_u32s(&payload);
        assert_eq!(decode_spans(&back).unwrap(), spans);
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        assert!(decode_spans(&[]).is_err());
        assert!(decode_spans(&[2, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
        // unknown kind code
        assert!(decode_spans(&[1, 0, 0, 0, 99, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn chrome_trace_has_expected_shape() {
        let doc = chrome_trace_json(&sample());
        let text = doc.to_compact();
        let parsed = Json::parse(&text).expect("trace must be valid JSON");
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        let e0 = &events[0];
        assert_eq!(e0.get("name").and_then(Json::as_str), Some("fwd_l0"));
        assert_eq!(e0.get("cat").and_then(Json::as_str), Some("compute"));
        assert_eq!(e0.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(e0.get("ts").and_then(Json::as_f64), Some(10.0));
        assert_eq!(e0.get("dur").and_then(Json::as_f64), Some(25.0));
        assert_eq!(e0.get("pid").and_then(Json::as_f64), Some(0.0));
        assert_eq!(e0.get("tid").and_then(Json::as_f64), Some(0.0));
        let e1 = &events[1];
        assert_eq!(e1.get("name").and_then(Json::as_str), Some("comm_wait_l2"));
        assert_eq!(e1.get("cat").and_then(Json::as_str), Some("comm"));
        assert_eq!(e1.get("tid").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn clock_sync_aligns_fabric_ranks() {
        // In-process Fabric: both "ranks" share a clock, so the
        // estimated offset must be ~0 (well under a second).
        enable();
        let fab = std::sync::Arc::new(crate::comm::Fabric::new(2));
        let server = {
            let fab = fab.clone();
            std::thread::spawn(move || serve_clock_sync(&*fab, 2))
        };
        let offset = clock_sync_offset(&*fab, 1);
        server.join().unwrap();
        assert!(offset.abs() < 1_000_000, "offset {offset}us");
    }

    #[test]
    fn ship_and_collect_merges_ranks() {
        enable();
        let fab = std::sync::Arc::new(crate::comm::Fabric::new(2));
        // distinctive epoch marker so concurrent tests recording into
        // the shared global buffer can't confuse the assertions
        span(1, Kind::BwdLayer, 1, 7777, now_us());
        span(0, Kind::FwdLayer, 0, 7777, now_us());
        let shipper = {
            let fab = fab.clone();
            std::thread::spawn(move || ship_spans(&*fab, 1))
        };
        let merged = collect_spans(&*fab, 2);
        shipper.join().unwrap();
        // whichever drain picked each span up, both must arrive exactly
        // once and the merge must be start-sorted
        assert!(merged.windows(2).all(|w| w[0].start_us <= w[1].start_us));
        let ours: Vec<_> = merged.iter().filter(|s| s.epoch == 7777).collect();
        assert_eq!(ours.len(), 2);
        assert!(ours.iter().any(|s| s.kind == Kind::BwdLayer && s.layer == 1));
        assert!(ours.iter().any(|s| s.kind == Kind::FwdLayer && s.layer == 0));
    }
}
