//! Observability: a lock-light metrics [`Registry`] (counters, gauges,
//! log-bucketed [`Histogram`]s with quantile estimates), a cross-rank
//! span [`trace`]r exporting Chrome trace-event JSON, and a live
//! Prometheus-text [`http`] exposition endpoint — all std-only.
//!
//! Hot paths hold pre-registered handles ([`Counter`] / [`Gauge`] /
//! [`Histogram`] are `Arc`-shared atomics), so an update is one or two
//! relaxed atomic ops; the registry mutex is only taken at registration
//! and at render time. Everything here is **observation-only**: nothing
//! touches message tags, payload values, or accumulation order, so loss
//! curves stay bit-identical with instrumentation on or off (pinned by
//! the engine-equivalence tests).
//!
//! Metric families render with a `pipegcn_` prefix in the exposition
//! format, e.g. `pipegcn_comm_wait_ms{key="fwd_l0"}` or
//! `pipegcn_link_bytes_sent_total{src="0",dst="1"}`; peak RSS is sampled
//! from `/proc/self/status` (`VmHWM`) at scrape time.

pub mod http;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Exposition-format prefix for every family this crate registers.
pub const PREFIX: &str = "pipegcn_";

// ---------------------------------------------------------------------
// Value handles
// ---------------------------------------------------------------------

/// Atomically add `delta` to an f64 stored as bits in an [`AtomicU64`].
///
/// Memory-ordering audit (the sanitizer CI jobs pin this): `Relaxed` is
/// correct throughout this module because metric cells are *values*,
/// never synchronization — no thread reads a cell to decide whether
/// another thread's non-atomic writes are visible. The CAS loop itself
/// is race-free at any ordering: `compare_exchange_weak` only commits
/// when the cell still holds the observed bits, so concurrent adds
/// serialize and no update is lost (the registry-exactness test hammers
/// this from the pool). Scrape-time reads may observe a slightly stale
/// value mid-update; that is inherent to sampling, not a data race.
fn f64_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Monotonically increasing f64 value (counts, bytes, accumulated ms).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, delta: f64) {
        debug_assert!(delta >= 0.0, "counters only go up");
        f64_add(&self.0, delta);
    }

    pub fn inc(&self) {
        self.add(1.0);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Instantaneous f64 value (depths, ages, norms, RSS).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, delta: f64) {
        f64_add(&self.0, delta);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------------------
// Log-bucketed histogram
// ---------------------------------------------------------------------

/// Buckets per doubling of the value — ratio 2^(1/4) ≈ 1.19, so a
/// quantile estimate (geometric bucket midpoint) is within ~9% of any
/// sample that landed in its bucket.
const HIST_SUB: f64 = 4.0;
/// Lowest bucket edge exponent: bucket 0 starts at 2^(-80/4) = 2^-20
/// (~9.5e-7). Values below (and ≤ 0) clamp into bucket 0.
const HIST_MIN: i64 = -80;
/// 240 buckets cover 2^-20 .. 2^40 (~1e-6 .. ~1e12); values above clamp
/// into the last bucket.
const HIST_BUCKETS: usize = 240;

struct HistCore {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bits (CAS-accumulated)
    sum: AtomicU64,
}

fn bucket_index(v: f64) -> usize {
    // NaN and everything ≤ 0 clamp into bucket 0
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    let idx = (v.log2() * HIST_SUB).floor() as i64 - HIST_MIN;
    idx.clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

/// Upper edge of bucket `i` (its `le` bound in the exposition format).
fn bucket_upper(i: usize) -> f64 {
    2f64.powf((i as i64 + HIST_MIN + 1) as f64 / HIST_SUB)
}

/// Geometric midpoint of bucket `i` — the quantile estimate.
fn bucket_mid(i: usize) -> f64 {
    2f64.powf((i as i64 + HIST_MIN) as f64 / HIST_SUB + 0.5 / HIST_SUB)
}

/// Log-bucketed histogram handle: `record` is two relaxed atomic
/// increments plus one CAS add; quantiles are estimated from the bucket
/// counts (geometric midpoint of the target bucket, relative error
/// bounded by the 2^(1/4) bucket ratio — asserted against the exact
/// [`crate::perf::percentile`] in `tests/obs.rs`).
#[derive(Clone)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    fn new() -> Histogram {
        Histogram(Arc::new(HistCore {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }

    pub fn record(&self, v: f64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        f64_add(&self.0.sum, v);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum.load(Ordering::Relaxed))
    }

    /// Estimated `q`-quantile (`0.0 ..= 1.0`): the geometric midpoint of
    /// the bucket holding the ceil(q·count)-th recorded value. 0 when
    /// nothing has been recorded.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.0.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_mid(i);
            }
        }
        bucket_mid(HIST_BUCKETS - 1)
    }

    /// Non-empty buckets as `(upper_edge, count)` pairs, ascending.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.0
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                if c > 0 {
                    Some((bucket_upper(i), c))
                } else {
                    None
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn type_name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Default)]
struct Inner {
    /// family name → kind (one `# TYPE` line each; kind mismatch panics)
    families: BTreeMap<String, Kind>,
    /// (family, rendered labels) → scalar cell
    nums: BTreeMap<(String, String), Arc<AtomicU64>>,
    /// (family, rendered labels) → histogram core
    hists: BTreeMap<(String, String), Arc<HistCore>>,
}

/// A named registry of metric families. Handles returned by
/// `counter`/`gauge`/`histogram` share their cells with the registry, so
/// updates through a handle are visible to [`Registry::render`] without
/// further registry locking.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

/// Render a label set as `k="v",k2="v2"` (sorted by key for stable
/// exposition output). Empty for no labels.
fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut sorted: Vec<&(&str, &str)> = labels.iter().collect();
    sorted.sort_by_key(|(k, _)| *k);
    sorted
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect::<Vec<_>>()
        .join(",")
}

/// Exposition value formatting: integral values render without a
/// decimal point (Rust's shortest-roundtrip `Display` already does
/// this: `12.0f64` prints as `12`).
fn render_value(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn family(&self, inner: &mut Inner, name: &str, kind: Kind) {
        match inner.families.get(name) {
            Some(&k) => assert_eq!(k, kind, "metric family '{name}' re-registered as {kind:?}"),
            None => {
                inner.families.insert(name.to_string(), kind);
            }
        }
    }

    fn num(&self, name: &str, labels: &[(&str, &str)], kind: Kind) -> Arc<AtomicU64> {
        let mut g = self.inner.lock().unwrap();
        self.family(&mut g, name, kind);
        g.nums
            .entry((name.to_string(), render_labels(labels)))
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone()
    }

    /// Register (or look up) a counter series. Same (name, labels) →
    /// the same underlying cell, so handles are safe to re-request.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        Counter(self.num(name, labels, Kind::Counter))
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        Gauge(self.num(name, labels, Kind::Gauge))
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        let mut g = self.inner.lock().unwrap();
        self.family(&mut g, name, Kind::Histogram);
        Histogram(
            g.hists
                .entry((name.to_string(), render_labels(labels)))
                .or_insert_with(|| Histogram::new().0)
                .clone(),
        )
    }

    /// Current value of a scalar series, if registered (tests).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        g.nums
            .get(&(name.to_string(), render_labels(labels)))
            .map(|c| f64::from_bits(c.load(Ordering::Relaxed)))
    }

    /// Render every family in the Prometheus text exposition format
    /// (families sorted by name, `pipegcn_` prefix applied).
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, &kind) in &g.families {
            out.push_str(&format!("# TYPE {PREFIX}{name} {}\n", kind.type_name()));
            match kind {
                Kind::Counter | Kind::Gauge => {
                    for ((fam, labels), cell) in g.nums.range((name.clone(), String::new())..) {
                        if fam != name {
                            break;
                        }
                        let v = f64::from_bits(cell.load(Ordering::Relaxed));
                        if labels.is_empty() {
                            out.push_str(&format!("{PREFIX}{name} {}\n", render_value(v)));
                        } else {
                            out.push_str(&format!(
                                "{PREFIX}{name}{{{labels}}} {}\n",
                                render_value(v)
                            ));
                        }
                    }
                }
                Kind::Histogram => {
                    for ((fam, labels), core) in g.hists.range((name.clone(), String::new())..) {
                        if fam != name {
                            break;
                        }
                        let h = Histogram(core.clone());
                        let mut cum = 0u64;
                        for (ub, c) in h.nonzero_buckets() {
                            cum += c;
                            let le = format!("le=\"{}\"", render_value(ub));
                            let ls = if labels.is_empty() {
                                le
                            } else {
                                format!("{labels},{le}")
                            };
                            out.push_str(&format!("{PREFIX}{name}_bucket{{{ls}}} {cum}\n"));
                        }
                        let inf = if labels.is_empty() {
                            "le=\"+Inf\"".to_string()
                        } else {
                            format!("{labels},le=\"+Inf\"")
                        };
                        out.push_str(&format!(
                            "{PREFIX}{name}_bucket{{{inf}}} {}\n",
                            h.count()
                        ));
                        let suffix = if labels.is_empty() {
                            String::new()
                        } else {
                            format!("{{{labels}}}")
                        };
                        out.push_str(&format!(
                            "{PREFIX}{name}_sum{suffix} {}\n",
                            render_value(h.sum())
                        ));
                        out.push_str(&format!("{PREFIX}{name}_count{suffix} {}\n", h.count()));
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// Global registry + common series
// ---------------------------------------------------------------------

static GLOBAL: Mutex<Option<Arc<Registry>>> = Mutex::new(None);

/// The process-wide registry every instrumented subsystem reports into
/// (and the [`http`] endpoint renders). Created on first use.
pub fn global() -> Arc<Registry> {
    let mut g = GLOBAL.lock().unwrap();
    match &*g {
        Some(r) => r.clone(),
        None => {
            let r = Arc::new(Registry::new());
            *g = Some(r.clone());
            r
        }
    }
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`); `None` where procfs is unavailable.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Sample peak RSS into the `peak_rss_bytes` gauge (called per epoch by
/// the engines and at scrape time by the endpoint).
pub fn sample_peak_rss(reg: &Registry) -> Option<u64> {
    let rss = peak_rss_bytes();
    if let Some(b) = rss {
        reg.gauge("peak_rss_bytes", &[]).set(b as f64);
    }
    rss
}

/// Publish one epoch's [`crate::comm::WaitStats`] breakdown into the
/// global registry: accumulated parked ms per schedule key plus the
/// hidden/exposed receive counters behind `overlap_ratio`.
pub fn record_wait_stats(stats: &crate::comm::WaitStats) {
    let reg = global();
    for (key, ms) in stats.entries_ms() {
        reg.counter("comm_wait_ms", &[("key", &key)]).add(ms);
    }
    reg.counter("recv_hidden_total", &[]).add(stats.hidden() as f64);
    reg.counter("recv_exposed_total", &[]).add(stats.exposed() as f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("frobs_total", &[("src", "0")]);
        c.inc();
        c.add(2.5);
        assert_eq!(r.value("frobs_total", &[("src", "0")]), Some(3.5));
        let g = r.gauge("depth", &[]);
        g.set(4.0);
        g.add(-1.0);
        assert_eq!(r.value("depth", &[]), Some(3.0));
        // the same (name, labels) resolves to the same cell
        r.counter("frobs_total", &[("src", "0")]).inc();
        assert_eq!(c.get(), 4.5);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(1.0);
        }
        for _ in 0..10 {
            h.record(100.0);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 1090.0).abs() < 1e-9);
        // p50 lands in the 1.0 bucket, p99 in the 100.0 bucket — each
        // estimate within the 2^(1/4) bucket ratio of the true value
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!((p50 / 1.0).log2().abs() <= 0.25 + 1e-9, "p50 {p50}");
        assert!((p99 / 100.0).log2().abs() <= 0.25 + 1e-9, "p99 {p99}");
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_clamps_pathological_values() {
        let h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(1e300);
        h.record(f64::NAN);
        assert_eq!(h.count(), 4);
        let b = h.nonzero_buckets();
        assert_eq!(b.iter().map(|&(_, c)| c).sum::<u64>(), 4);
    }

    #[test]
    fn render_is_prometheus_text() {
        let r = Registry::new();
        r.counter("bytes_total", &[("src", "0"), ("dst", "1")]).add(64.0);
        r.gauge("depth", &[]).set(2.0);
        r.histogram("lat_ms", &[]).record(1.5);
        let text = r.render();
        assert!(text.contains("# TYPE pipegcn_bytes_total counter"), "{text}");
        assert!(
            text.contains("pipegcn_bytes_total{dst=\"1\",src=\"0\"} 64"),
            "{text}"
        );
        assert!(text.contains("# TYPE pipegcn_depth gauge"), "{text}");
        assert!(text.contains("pipegcn_depth 2\n"), "{text}");
        assert!(text.contains("pipegcn_lat_ms_count 1"), "{text}");
        assert!(text.contains("le=\"+Inf\""), "{text}");
        assert!(text.contains("pipegcn_lat_ms_sum 1.5"), "{text}");
    }

    #[test]
    fn peak_rss_is_plausible_on_linux() {
        if let Some(b) = peak_rss_bytes() {
            // any live process has used at least a page and well under 1 TiB
            assert!(b >= 4096, "{b}");
            assert!(b < (1u64 << 40), "{b}");
        }
    }

    #[test]
    fn global_registry_is_shared() {
        let a = global();
        let b = global();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
