//! Graph partitioning.
//!
//! The paper partitions with METIS, objective = minimize communication
//! volume. METIS is not available here, so [`multilevel`] reimplements the
//! same scheme from scratch (heavy-edge-matching coarsening → greedy
//! initial partition → FM boundary refinement); [`simple`] provides
//! hash / range / BFS baselines used in partitioner-quality comparisons.

pub mod multilevel;
pub mod simple;

use crate::graph::{Adj, Graph};

/// A k-way node assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Partitioning {
    pub n_parts: usize,
    /// `assign[v] ∈ [0, n_parts)`
    pub assign: Vec<u32>,
}

impl Partitioning {
    pub fn new(n_parts: usize, assign: Vec<u32>) -> Partitioning {
        debug_assert!(assign.iter().all(|&p| (p as usize) < n_parts));
        Partitioning { n_parts, assign }
    }

    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_parts];
        for &p in &self.assign {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Node ids of each part, sorted.
    pub fn members(&self) -> Vec<Vec<u32>> {
        let mut m = vec![Vec::new(); self.n_parts];
        for (v, &p) in self.assign.iter().enumerate() {
            m[p as usize].push(v as u32);
        }
        m
    }

    /// Invariants: all nodes assigned, every part non-empty (when
    /// n ≥ n_parts).
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.assign.len() != n {
            return Err(format!("assign len {} != n {}", self.assign.len(), n));
        }
        let sizes = self.part_sizes();
        if n >= self.n_parts && sizes.iter().any(|&s| s == 0) {
            return Err(format!("empty part in sizes {:?}", sizes));
        }
        Ok(())
    }
}

/// Partition quality metrics (paper §4: METIS objective = communication
/// volume; we also report edge cut, replication factor, balance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quality {
    /// #undirected edges crossing parts
    pub edge_cut: usize,
    /// Σ_v #distinct foreign parts containing a neighbor of v — the number
    /// of boundary-node replicas, i.e. the per-layer communication volume
    /// in node-feature units.
    pub comm_volume: usize,
    /// (inner + replica nodes) / inner nodes
    pub replication_factor: f64,
    /// max part size / average part size
    pub balance: f64,
}

impl Quality {
    /// The JSON shape run-log headers, worker reports, and
    /// [`crate::session::RunReport`] outputs all share.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("edge_cut", self.edge_cut)
            .set("comm_volume", self.comm_volume)
            .set("replication_factor", self.replication_factor)
            .set("balance", self.balance)
    }

    /// Inverse of [`Quality::to_json`] (tolerant: `None` when any field
    /// is missing — old run artifacts predate the quality block).
    pub fn from_json(j: &crate::util::json::Json) -> Option<Quality> {
        Some(Quality {
            edge_cut: j.get("edge_cut")?.as_usize()?,
            comm_volume: j.get("comm_volume")?.as_usize()?,
            replication_factor: j.get("replication_factor")?.as_f64()?,
            balance: j.get("balance")?.as_f64()?,
        })
    }
}

/// Incremental [`Quality`] accumulator: feed each node exactly once (any
/// order, e.g. one rank's nodes at a time on the scale path) with its
/// part and its neighbors' parts, then [`QualityAccum::finish`]. O(parts)
/// scratch, no materialized `Graph` required.
pub struct QualityAccum {
    n_parts: usize,
    n: usize,
    edge_cut: usize,
    comm_volume: usize,
    /// per-part marker of the last node that touched it (dedup scratch)
    seen: Vec<u32>,
    sizes: Vec<usize>,
}

impl QualityAccum {
    pub fn new(n_parts: usize) -> QualityAccum {
        QualityAccum {
            n_parts,
            n: 0,
            edge_cut: 0,
            comm_volume: 0,
            seen: vec![u32::MAX; n_parts],
            sizes: vec![0; n_parts],
        }
    }

    /// Account node `v` (in part `pv`) given its neighbor list as
    /// `(neighbor id, neighbor part)` pairs. Each undirected edge is seen
    /// from both endpoints across the full visit sequence; the cut is
    /// counted on the `v < u` side only.
    pub fn visit(&mut self, v: usize, pv: u32, neighbors: impl Iterator<Item = (u32, u32)>) {
        self.n += 1;
        self.sizes[pv as usize] += 1;
        for (u, pu) in neighbors {
            if pu != pv {
                if v < u as usize {
                    self.edge_cut += 1;
                }
                if self.seen[pu as usize] != v as u32 {
                    self.seen[pu as usize] = v as u32;
                    self.comm_volume += 1;
                }
            }
        }
    }

    pub fn finish(&self) -> Quality {
        let max = *self.sizes.iter().max().unwrap_or(&0) as f64;
        let avg = self.n as f64 / self.n_parts as f64;
        Quality {
            edge_cut: self.edge_cut,
            comm_volume: self.comm_volume,
            replication_factor: if self.n > 0 {
                (self.n + self.comm_volume) as f64 / self.n as f64
            } else {
                0.0
            },
            balance: if avg > 0.0 { max / avg } else { 0.0 },
        }
    }
}

/// Compute quality metrics of `p` over adjacency structure alone.
pub fn quality_adj(adj: Adj<'_>, p: &Partitioning) -> Quality {
    assert_eq!(p.assign.len(), adj.n);
    let mut acc = QualityAccum::new(p.n_parts);
    for v in 0..adj.n {
        acc.visit(
            v,
            p.assign[v],
            adj.neighbors(v).iter().map(|&u| (u, p.assign[u as usize])),
        );
    }
    acc.finish()
}

/// Compute quality metrics of `p` on `g`.
pub fn quality(g: &Graph, p: &Partitioning) -> Quality {
    quality_adj(g.adj(), p)
}

/// Method selector used by the CLI and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Multilevel,
    Hash,
    Range,
    Bfs,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "multilevel" | "metis" => Some(Method::Multilevel),
            // "simple" is the escape hatch from the multilevel default
            "hash" | "simple" => Some(Method::Hash),
            "range" => Some(Method::Range),
            "bfs" => Some(Method::Bfs),
            _ => None,
        }
    }
}

/// Partition adjacency structure into `k` parts with the chosen method
/// (deterministic in `seed`) — the scale-path entry point: a feature-free
/// [`crate::graph::Topology`] is enough.
pub fn partition_adj(adj: Adj<'_>, k: usize, method: Method, seed: u64) -> Partitioning {
    match method {
        Method::Multilevel => multilevel::partition_adj(adj, k, seed),
        Method::Hash => simple::hash_partition(adj.n, k),
        Method::Range => simple::range_partition(adj.n, k),
        Method::Bfs => simple::bfs_partition_adj(adj, k, seed),
    }
}

/// Partition `g` into `k` parts with the chosen method (deterministic in
/// `seed`).
pub fn partition(g: &Graph, k: usize, method: Method, seed: u64) -> Partitioning {
    partition_adj(g.adj(), k, method, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, Labels};
    use crate::tensor::Mat;

    fn path_graph(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> = (0..n - 1).map(|i| (i as u32, i as u32 + 1)).collect();
        Graph::from_edges(
            n,
            &edges,
            Mat::zeros(n, 1),
            Labels::Single { labels: vec![0; n], n_classes: 1 },
        )
    }

    #[test]
    fn quality_on_path_range_split() {
        let g = path_graph(10);
        let p = simple::range_partition(10, 2);
        let q = quality(&g, &p);
        assert_eq!(q.edge_cut, 1);
        assert_eq!(q.comm_volume, 2); // node 4 needed by part 1, node 5 by part 0
        assert!((q.balance - 1.0).abs() < 1e-9);
    }

    #[test]
    fn comm_volume_counts_distinct_parts_once() {
        // star: center 0 connected to 1,2,3; assign center alone in part 0,
        // leaves spread over parts 1,1,2 → center replicated to parts 1,2
        let g = Graph::from_edges(
            4,
            &[(0, 1), (0, 2), (0, 3)],
            Mat::zeros(4, 1),
            Labels::Single { labels: vec![0; 4], n_classes: 1 },
        );
        let p = Partitioning::new(3, vec![0, 1, 1, 2]);
        let q = quality(&g, &p);
        // v=0 replicated into parts {1,2} = 2; each leaf replicated into {0} = 3
        assert_eq!(q.comm_volume, 5);
        assert_eq!(q.edge_cut, 3);
    }

    #[test]
    fn partition_methods_all_valid() {
        let mut rng = crate::util::rng::Rng::new(5);
        let cfg = generate::SbmConfig::new(400, 8, 8.0, 2.0);
        let g = generate::sbm_dataset(&cfg, 4, 8, false, 0.5, &mut rng);
        for m in [Method::Multilevel, Method::Hash, Method::Range, Method::Bfs] {
            let p = partition(&g, 4, m, 1);
            p.validate(g.n).unwrap_or_else(|e| panic!("{m:?}: {e}"));
        }
    }
}
