//! Baseline partitioners: hash, contiguous range, and BFS region growing.

use super::Partitioning;
use crate::graph::{Adj, Graph};
use crate::util::rng::Rng;

/// `assign[v] = v mod k` — the "no locality" strawman.
pub fn hash_partition(n: usize, k: usize) -> Partitioning {
    Partitioning::new(k, (0..n).map(|v| (v % k) as u32).collect())
}

/// Contiguous index ranges of (near-)equal size.
pub fn range_partition(n: usize, k: usize) -> Partitioning {
    let mut assign = vec![0u32; n];
    let base = n / k;
    let extra = n % k;
    let mut v = 0usize;
    for p in 0..k {
        let sz = base + usize::from(p < extra);
        for _ in 0..sz {
            assign[v] = p as u32;
            v += 1;
        }
    }
    Partitioning::new(k, assign)
}

/// Balanced multi-source BFS growing: k random seeds expand in lockstep,
/// each capped at ⌈n/k⌉ nodes; leftovers (disconnected) round-robin.
pub fn bfs_partition(g: &Graph, k: usize, seed: u64) -> Partitioning {
    bfs_partition_adj(g.adj(), k, seed)
}

/// [`bfs_partition`] over adjacency structure alone.
pub fn bfs_partition_adj(g: Adj<'_>, k: usize, seed: u64) -> Partitioning {
    let n = g.n;
    let mut rng = Rng::new(seed ^ 0xBF5);
    let cap = n.div_ceil(k);
    let mut assign = vec![u32::MAX; n];
    let mut queues: Vec<std::collections::VecDeque<u32>> =
        (0..k).map(|_| std::collections::VecDeque::new()).collect();
    let mut sizes = vec![0usize; k];
    let seeds = rng.sample_indices(n, k.min(n));
    for (p, &s) in seeds.iter().enumerate() {
        assign[s] = p as u32;
        sizes[p] += 1;
        queues[p].push_back(s as u32);
    }
    let mut active = true;
    while active {
        active = false;
        for p in 0..k {
            if sizes[p] >= cap {
                continue;
            }
            // expand one frontier node per round for balance
            while let Some(v) = queues[p].pop_front() {
                let mut grew = false;
                for &u in g.neighbors(v as usize) {
                    if assign[u as usize] == u32::MAX && sizes[p] < cap {
                        assign[u as usize] = p as u32;
                        sizes[p] += 1;
                        queues[p].push_back(u);
                        grew = true;
                    }
                }
                active = true;
                if grew {
                    break;
                }
            }
        }
    }
    // unreached nodes (isolated / cap overflow): fill smallest parts
    for v in 0..n {
        if assign[v] == u32::MAX {
            let p = (0..k).min_by_key(|&p| sizes[p]).unwrap();
            assign[v] = p as u32;
            sizes[p] += 1;
        }
    }
    Partitioning::new(k, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, Labels};
    use crate::tensor::Mat;

    #[test]
    fn hash_balanced() {
        let p = hash_partition(10, 3);
        let sizes = p.part_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn range_contiguous() {
        let p = range_partition(10, 2);
        assert_eq!(p.assign, vec![0, 0, 0, 0, 0, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn bfs_covers_and_balances() {
        let mut rng = crate::util::rng::Rng::new(3);
        let cfg = generate::SbmConfig::new(300, 6, 6.0, 1.0);
        let g = generate::sbm_dataset(&cfg, 4, 6, false, 0.5, &mut rng);
        let p = bfs_partition(&g, 4, 1);
        p.validate(g.n).unwrap();
        let sizes = p.part_sizes();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max <= 76, "max {max}");
        assert!(min >= 50, "min {min}"); // reasonably balanced
    }

    #[test]
    fn bfs_handles_disconnected() {
        // two disjoint edges + isolated node
        let g = Graph::from_edges(
            5,
            &[(0, 1), (2, 3)],
            Mat::zeros(5, 1),
            Labels::Single { labels: vec![0; 5], n_classes: 1 },
        );
        let p = bfs_partition(&g, 2, 0);
        p.validate(5).unwrap();
    }
}
