//! METIS-like multilevel k-way partitioner.
//!
//! Three phases, as in Karypis & Kumar (1998):
//! 1. **Coarsening** — heavy-edge matching (HEM): repeatedly contract a
//!    maximal matching that prefers heavy edges, accumulating node and
//!    edge weights, until the graph is small or contraction stalls.
//! 2. **Initial partition** — balanced greedy region growing on the
//!    coarsest graph (k seeds, grow by best-gain frontier node).
//! 3. **Uncoarsening + refinement** — project the assignment back level
//!    by level, then run boundary FM passes: move boundary nodes to the
//!    neighboring part with the best edge-cut gain subject to a balance
//!    constraint.
//!
//! The refinement objective is weighted edge cut, the classic METIS
//! objective that the paper's `objtype=vol` variant closely tracks on
//! these graphs; `partition::quality` reports both.

use super::Partitioning;
use crate::graph::{Adj, Graph};
use crate::util::rng::Rng;

/// Internal weighted graph (CSR) used across coarsening levels.
struct WGraph {
    n: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    ewgt: Vec<u64>,
    vwgt: Vec<u64>,
}

impl WGraph {
    fn from_adj(adj: Adj<'_>) -> WGraph {
        WGraph {
            n: adj.n,
            indptr: adj.indptr.to_vec(),
            indices: adj.indices.to_vec(),
            ewgt: vec![1; adj.indices.len()],
            vwgt: vec![1; adj.n],
        }
    }

    fn neighbors(&self, v: usize) -> impl Iterator<Item = (u32, u64)> + '_ {
        let lo = self.indptr[v];
        let hi = self.indptr[v + 1];
        self.indices[lo..hi].iter().zip(&self.ewgt[lo..hi]).map(|(&u, &w)| (u, w))
    }

    fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().sum()
    }
}

/// Heavy-edge matching: returns `match_of[v]` (= v if unmatched) and the
/// coarse-node map `cmap[v]`.
fn heavy_edge_matching(g: &WGraph, rng: &mut Rng) -> (Vec<u32>, usize) {
    let n = g.n;
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    for &v in &order {
        let v = v as usize;
        if matched[v] != u32::MAX {
            continue;
        }
        // heaviest unmatched neighbor
        let mut best: Option<(u32, u64)> = None;
        for (u, w) in g.neighbors(v) {
            if matched[u as usize] == u32::MAX && u as usize != v {
                if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                    best = Some((u, w));
                }
            }
        }
        match best {
            Some((u, _)) => {
                matched[v] = u;
                matched[u as usize] = v as u32;
            }
            None => matched[v] = v as u32,
        }
    }
    // assign coarse ids
    let mut cmap = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if cmap[v] == u32::MAX {
            let m = matched[v] as usize;
            cmap[v] = next;
            cmap[m] = next;
            next += 1;
        }
    }
    (cmap, next as usize)
}

/// Contract `g` by `cmap` into `cn` coarse nodes, summing weights.
fn contract(g: &WGraph, cmap: &[u32], cn: usize) -> WGraph {
    let mut vwgt = vec![0u64; cn];
    for v in 0..g.n {
        vwgt[cmap[v] as usize] += g.vwgt[v];
    }
    // accumulate coarse edges via hashmap per coarse node
    let mut adj: Vec<std::collections::HashMap<u32, u64>> =
        vec![std::collections::HashMap::new(); cn];
    for v in 0..g.n {
        let cv = cmap[v];
        for (u, w) in g.neighbors(v) {
            let cu = cmap[u as usize];
            if cu != cv {
                *adj[cv as usize].entry(cu).or_insert(0) += w;
            }
        }
    }
    let mut indptr = vec![0usize; cn + 1];
    let mut indices = Vec::new();
    let mut ewgt = Vec::new();
    for v in 0..cn {
        let mut entries: Vec<(u32, u64)> = adj[v].iter().map(|(&u, &w)| (u, w)).collect();
        entries.sort_unstable_by_key(|&(u, _)| u);
        for (u, w) in entries {
            indices.push(u);
            ewgt.push(w);
        }
        indptr[v + 1] = indices.len();
    }
    WGraph { n: cn, indptr, indices, ewgt, vwgt }
}

/// Balanced greedy region growing on the (coarse) weighted graph.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.n;
    let total = g.total_vwgt();
    let cap = (total as f64 / k as f64 * 1.1).ceil() as u64;
    let mut assign = vec![u32::MAX; n];
    let mut load = vec![0u64; k];
    // seeds: spread-out random nodes
    let seeds = rng.sample_indices(n, k.min(n));
    let mut frontiers: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (p, &s) in seeds.iter().enumerate() {
        assign[s] = p as u32;
        load[p] += g.vwgt[s];
        frontiers[p] = g.neighbors(s).map(|(u, _)| u).collect();
    }
    loop {
        let mut progress = false;
        // lightest part grows first
        let mut parts: Vec<usize> = (0..k).collect();
        parts.sort_unstable_by_key(|&p| load[p]);
        for &p in &parts {
            if load[p] >= cap {
                continue;
            }
            // pop an unassigned frontier node (gain ordering approximated
            // by FIFO over the frontier, cheap and effective at this size)
            while let Some(v) = frontiers[p].pop() {
                let v = v as usize;
                if assign[v] != u32::MAX {
                    continue;
                }
                assign[v] = p as u32;
                load[p] += g.vwgt[v];
                for (u, _) in g.neighbors(v) {
                    if assign[u as usize] == u32::MAX {
                        frontiers[p].push(u);
                    }
                }
                progress = true;
                break;
            }
        }
        if !progress {
            break;
        }
    }
    // leftovers (disconnected or capped out) → lightest part
    for v in 0..n {
        if assign[v] == u32::MAX {
            let p = (0..k).min_by_key(|&p| load[p]).unwrap();
            assign[v] = p as u32;
            load[p] += g.vwgt[v];
        }
    }
    assign
}

/// Boundary FM refinement on the weighted graph: `passes` greedy sweeps
/// moving boundary nodes to the best-gain part under the balance cap.
fn refine(g: &WGraph, assign: &mut [u32], k: usize, passes: usize, rng: &mut Rng) {
    let total = g.total_vwgt();
    let cap = (total as f64 / k as f64 * 1.05).ceil() as u64;
    let min_cap = (total as f64 / k as f64 * 0.6).floor() as u64;
    let mut load = vec![0u64; k];
    for v in 0..g.n {
        load[assign[v] as usize] += g.vwgt[v];
    }
    let mut conn = vec![0u64; k]; // scratch: edge weight to each part
    for _ in 0..passes {
        let mut moved = 0usize;
        let mut order: Vec<u32> = (0..g.n as u32).collect();
        rng.shuffle(&mut order);
        for &v in &order {
            let v = v as usize;
            let pv = assign[v] as usize;
            // connectivity to each part
            let mut touched: Vec<usize> = Vec::with_capacity(8);
            for (u, w) in g.neighbors(v) {
                let pu = assign[u as usize] as usize;
                if conn[pu] == 0 {
                    touched.push(pu);
                }
                conn[pu] += w;
            }
            if touched.is_empty() || (touched.len() == 1 && touched[0] == pv) {
                for &t in &touched {
                    conn[t] = 0;
                }
                continue; // interior node
            }
            let here = conn[pv];
            let mut best: Option<(usize, i64)> = None;
            for &t in &touched {
                if t == pv {
                    continue;
                }
                let gain = conn[t] as i64 - here as i64;
                if load[t] + g.vwgt[v] <= cap
                    && load[pv] >= min_cap + g.vwgt[v]
                    && best.map(|(_, bg)| gain > bg).unwrap_or(true)
                {
                    best = Some((t, gain));
                }
            }
            if let Some((t, gain)) = best {
                if gain > 0 || (gain == 0 && load[pv] > load[t] + g.vwgt[v]) {
                    assign[v] = t as u32;
                    load[pv] -= g.vwgt[v];
                    load[t] += g.vwgt[v];
                    moved += 1;
                }
            }
            for &t in &touched {
                conn[t] = 0;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Multilevel k-way partition of `g` (deterministic in `seed`).
pub fn partition(g: &Graph, k: usize, seed: u64) -> Partitioning {
    partition_adj(g.adj(), k, seed)
}

/// [`partition`] over adjacency structure alone — the quality/scale
/// workhorse: a feature-free [`crate::graph::Topology`] view is all the
/// coarsening pipeline ever reads, so the scale path partitions without
/// materializing a `Graph`. Bit-identical to `partition` on the same
/// structure and seed.
pub fn partition_adj(adj: Adj<'_>, k: usize, seed: u64) -> Partitioning {
    assert!(k >= 1);
    let mut rng = Rng::new(seed ^ 0x9A37171);
    if k == 1 {
        return Partitioning::new(1, vec![0; adj.n]);
    }
    let mut levels: Vec<WGraph> = vec![WGraph::from_adj(adj)];
    let mut cmaps: Vec<Vec<u32>> = Vec::new();
    // coarsen until small or stalled
    let target = (k * 24).max(128);
    loop {
        let cur = levels.last().unwrap();
        if cur.n <= target {
            break;
        }
        let (cmap, cn) = heavy_edge_matching(cur, &mut rng);
        if cn as f64 > cur.n as f64 * 0.95 {
            break; // stalled (e.g. star graphs)
        }
        let coarse = contract(cur, &cmap, cn);
        cmaps.push(cmap);
        levels.push(coarse);
    }
    // initial partition on coarsest: multiple restarts, keep best cut
    // (greedy growing + positive-gain FM is seed-sensitive; restarts are
    // cheap at coarse size and recover cluster-aligned partitions)
    let coarsest = levels.last().unwrap();
    let cut_of = |g: &WGraph, assign: &[u32]| -> u64 {
        let mut cut = 0u64;
        for v in 0..g.n {
            for (u, w) in g.neighbors(v) {
                if assign[v] != assign[u as usize] {
                    cut += w;
                }
            }
        }
        cut / 2
    };
    let mut assign = Vec::new();
    let mut best_cut = u64::MAX;
    for restart in 0..8 {
        let mut r = rng.fork(restart);
        let mut cand = initial_partition(coarsest, k, &mut r);
        refine(coarsest, &mut cand, k, 8, &mut r);
        let cut = cut_of(coarsest, &cand);
        if cut < best_cut {
            best_cut = cut;
            assign = cand;
        }
    }
    // uncoarsen with refinement at each level
    for lvl in (0..cmaps.len()).rev() {
        let fine = &levels[lvl];
        let cmap = &cmaps[lvl];
        let mut fine_assign = vec![0u32; fine.n];
        for v in 0..fine.n {
            fine_assign[v] = assign[cmap[v] as usize];
        }
        refine(fine, &mut fine_assign, k, 6, &mut rng);
        assign = fine_assign;
    }
    // safety: no empty parts — steal from the largest part's boundary
    let mut sizes = vec![0usize; k];
    for &p in &assign {
        sizes[p as usize] += 1;
    }
    for p in 0..k {
        while sizes[p] == 0 {
            let donor = (0..k).max_by_key(|&q| sizes[q]).unwrap();
            if let Some(v) = assign.iter().position(|&a| a as usize == donor) {
                assign[v] = p as u32;
                sizes[donor] -= 1;
                sizes[p] += 1;
            } else {
                break;
            }
        }
    }
    Partitioning::new(k, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, Labels};
    use crate::partition::{quality, simple};
    use crate::tensor::Mat;

    fn sbm(n: usize, k: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let cfg = generate::SbmConfig::new(n, k, 8.0, 1.0);
        generate::sbm_dataset(&cfg, 4, k, false, 0.5, &mut rng)
    }

    #[test]
    fn grid_bisection_near_optimal() {
        let edges = generate::grid2d_edges(16, 16);
        let g = Graph::from_edges(
            256,
            &edges,
            Mat::zeros(256, 1),
            Labels::Single { labels: vec![0; 256], n_classes: 1 },
        );
        let p = partition(&g, 2, 1);
        p.validate(g.n).unwrap();
        let q = quality(&g, &p);
        // optimal bisection cut = 16; accept anything close
        assert!(q.edge_cut <= 28, "edge cut {}", q.edge_cut);
        assert!(q.balance < 1.1, "balance {}", q.balance);
    }

    #[test]
    fn beats_hash_on_sbm() {
        let g = sbm(800, 8, 2);
        let ml = partition(&g, 8, 1);
        let hash = simple::hash_partition(g.n, 8);
        let qm = quality(&g, &ml);
        let qh = quality(&g, &hash);
        assert!(
            (qm.comm_volume as f64) < 0.5 * qh.comm_volume as f64,
            "multilevel {} vs hash {}",
            qm.comm_volume,
            qh.comm_volume
        );
        assert!(qm.balance < 1.15, "balance {}", qm.balance);
    }

    #[test]
    fn recovers_sbm_communities_roughly() {
        let g = sbm(600, 4, 3);
        let p = partition(&g, 4, 7);
        let q = quality(&g, &p);
        // intra-degree 8, inter 1 → a community-aligned partition cuts
        // roughly the inter edges only (~n/2 * 1 = 300); allow slack
        assert!(q.edge_cut < 700, "edge cut {}", q.edge_cut);
    }

    #[test]
    fn many_parts_all_nonempty() {
        let g = sbm(500, 10, 4);
        for k in [2, 3, 5, 10, 16] {
            let p = partition(&g, k, 11);
            p.validate(g.n).unwrap_or_else(|e| panic!("k={k}: {e}"));
            let q = quality(&g, &p);
            assert!(q.balance < 1.6, "k={k} balance {}", q.balance);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let g = sbm(300, 4, 5);
        let a = partition(&g, 4, 9);
        let b = partition(&g, 4, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn k_equals_one() {
        let g = sbm(100, 2, 6);
        let p = partition(&g, 1, 0);
        assert!(p.assign.iter().all(|&a| a == 0));
    }
}
