//! Graph substrate: the in-memory graph type, synthetic dataset
//! generators, feature/label synthesis, GCN normalization, binary IO,
//! and the dataset presets that mirror the paper's four benchmarks.

pub mod generate;
pub mod features;
pub mod io;
pub mod presets;

use crate::tensor::{Csr, Mat};

/// Node labels: single-label classification (Reddit/ogbn-products style)
/// or multi-label (Yelp style).
#[derive(Clone, Debug, PartialEq)]
pub enum Labels {
    /// `labels[v] ∈ [0, n_classes)`
    Single { labels: Vec<u32>, n_classes: usize },
    /// rows×classes {0,1} indicator matrix
    Multi { targets: Mat },
}

impl Labels {
    pub fn n_classes(&self) -> usize {
        match self {
            Labels::Single { n_classes, .. } => *n_classes,
            Labels::Multi { targets } => targets.cols,
        }
    }

    pub fn is_multilabel(&self) -> bool {
        matches!(self, Labels::Multi { .. })
    }
}

/// Borrowed adjacency-only view over CSR storage — the lightweight
/// degree/edge summary partitioners and halo assembly consume, so they
/// work identically over a full [`Graph`] or a feature-free
/// [`Topology`].
#[derive(Clone, Copy, Debug)]
pub struct Adj<'a> {
    pub n: usize,
    pub indptr: &'a [usize],
    pub indices: &'a [u32],
}

impl<'a> Adj<'a> {
    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    pub fn neighbors(&self, v: usize) -> &'a [u32] {
        &self.indices[self.indptr[v]..self.indptr[v + 1]]
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }
}

/// Feature-free adjacency: node count + CSR structure only. The scale
/// path holds one of these per rank — partitioning, global degrees, and
/// halo/send-set assembly need the structure, while features and labels
/// stay sharded per partition (see [`generate::Shard`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub n: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
}

impl Topology {
    /// Build CSR adjacency from an undirected edge list — same
    /// symmetrize/dedup semantics as [`Graph::from_edges`], so both
    /// produce bit-identical structure from the same edges.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Topology {
        let (indptr, indices) = csr_from_edges(n, edges);
        Topology { n, indptr, indices }
    }

    pub fn adj(&self) -> Adj<'_> {
        Adj { n: self.n, indptr: &self.indptr, indices: &self.indices }
    }

    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v]..self.indptr[v + 1]]
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }
}

/// Symmetrize + dedup an undirected edge list into sorted CSR adjacency
/// (self-loops dropped). Shared by [`Graph::from_edges`] and
/// [`Topology::from_edges`].
fn csr_from_edges(n: usize, edges: &[(u32, u32)]) -> (Vec<usize>, Vec<u32>) {
    let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(edges.len() * 2);
    for &(u, v) in edges {
        debug_assert!((u as usize) < n && (v as usize) < n);
        if u == v {
            continue;
        }
        pairs.push((u, v));
        pairs.push((v, u));
    }
    pairs.sort_unstable();
    pairs.dedup();
    let mut indptr = vec![0usize; n + 1];
    let mut indices = Vec::with_capacity(pairs.len());
    for &(u, v) in &pairs {
        indptr[u as usize + 1] += 1;
        indices.push(v);
    }
    for i in 0..n {
        indptr[i + 1] += indptr[i];
    }
    (indptr, indices)
}

/// The split sampler behind [`Graph::random_split`] and the sharded
/// dataset builders: one shuffle of all ids, then sorted train/val/test
/// slices. The RNG consumption must stay byte-stable — shard replay
/// depends on drawing the exact same stream.
pub fn split_ids(
    n: usize,
    train_frac: f64,
    val_frac: f64,
    rng: &mut crate::util::rng::Rng,
) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let n_train = (n as f64 * train_frac).round() as usize;
    let n_val = (n as f64 * val_frac).round() as usize;
    let mut train = ids[..n_train].to_vec();
    let mut val = ids[n_train..(n_train + n_val).min(n)].to_vec();
    let mut test = ids[(n_train + n_val).min(n)..].to_vec();
    train.sort_unstable();
    val.sort_unstable();
    test.sort_unstable();
    (train, val, test)
}

/// An undirected graph in CSR adjacency form with node features, labels,
/// and train/val/test splits (sorted node-id lists).
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// CSR adjacency: `indptr.len() == n+1`; neighbor lists sorted,
    /// both directions present, no self-loops stored (the GCN
    /// normalization adds Ã = A + I itself).
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub features: Mat,
    pub labels: Labels,
    pub train_mask: Vec<u32>,
    pub val_mask: Vec<u32>,
    pub test_mask: Vec<u32>,
}

impl Graph {
    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v]..self.indptr[v + 1]]
    }

    pub fn feat_dim(&self) -> usize {
        self.features.cols
    }

    pub fn adj(&self) -> Adj<'_> {
        Adj { n: self.n, indptr: &self.indptr, indices: &self.indices }
    }

    /// Build CSR adjacency from an undirected edge list (u, v), u != v.
    /// Deduplicates and symmetrizes.
    pub fn from_edges(n: usize, edges: &[(u32, u32)], features: Mat, labels: Labels) -> Graph {
        assert_eq!(features.rows, n);
        let (indptr, indices) = csr_from_edges(n, edges);
        Graph {
            n,
            indptr,
            indices,
            features,
            labels,
            train_mask: Vec::new(),
            val_mask: Vec::new(),
            test_mask: Vec::new(),
        }
    }

    /// Normalized degree vector `d̃_v = deg(v) + 1` (Ã = A + I).
    pub fn degrees_tilde(&self) -> Vec<f32> {
        (0..self.n).map(|v| (self.degree(v) + 1) as f32).collect()
    }

    /// GCN propagation matrix `P = D̃^{-1/2} Ã D̃^{-1/2}` with `Ã = A + I`
    /// over the **full** graph (reference semantics; the partitioned
    /// equivalent is assembled per-partition by `coordinator::halo`).
    pub fn propagation_matrix(&self) -> Csr {
        let deg_t = self.degrees_tilde();
        let mut trip = Vec::with_capacity(self.indices.len() + self.n);
        for v in 0..self.n {
            let dv = deg_t[v];
            // self-loop weight 1/d̃_v = 1/(√d̃_v·√d̃_v)
            trip.push((v as u32, v as u32, 1.0 / dv));
            for &u in self.neighbors(v) {
                trip.push((v as u32, u, 1.0 / (dv.sqrt() * deg_t[u as usize].sqrt())));
            }
        }
        Csr::from_triplets(self.n, self.n, trip)
    }

    /// Mean-aggregator propagation `P = D̃^{-1} Ã` (GraphSAGE-mean as in
    /// Eq. 3 of the paper, including the node itself).
    pub fn mean_propagation_matrix(&self) -> Csr {
        let mut trip = Vec::with_capacity(self.indices.len() + self.n);
        for v in 0..self.n {
            let inv = 1.0 / (self.degree(v) + 1) as f32;
            trip.push((v as u32, v as u32, inv));
            for &u in self.neighbors(v) {
                trip.push((v as u32, u, inv));
            }
        }
        Csr::from_triplets(self.n, self.n, trip)
    }

    /// Random train/val/test split with the given fractions.
    pub fn random_split(&mut self, train_frac: f64, val_frac: f64, rng: &mut crate::util::rng::Rng) {
        let (train, val, test) = split_ids(self.n, train_frac, val_frac, rng);
        self.train_mask = train;
        self.val_mask = val;
        self.test_mask = test;
    }

    /// Sanity invariants (used by tests and after IO round-trips).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.n + 1 {
            return Err("indptr length".into());
        }
        if *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr tail".into());
        }
        for v in 0..self.n {
            let nb = self.neighbors(v);
            if !nb.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("neighbors of {v} not sorted/unique"));
            }
            for &u in nb {
                if u as usize >= self.n {
                    return Err("neighbor out of range".into());
                }
                if u as usize == v {
                    return Err("self loop stored".into());
                }
                if self.neighbors(u as usize).binary_search(&(v as u32)).is_err() {
                    return Err(format!("edge {v}->{u} not symmetric"));
                }
            }
        }
        if self.features.rows != self.n {
            return Err("features rows".into());
        }
        match &self.labels {
            Labels::Single { labels, n_classes } => {
                if labels.len() != self.n {
                    return Err("labels len".into());
                }
                if labels.iter().any(|&l| l as usize >= *n_classes) {
                    return Err("label out of range".into());
                }
            }
            Labels::Multi { targets } => {
                if targets.rows != self.n {
                    return Err("targets rows".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn triangle() -> Graph {
        let feats = Mat::zeros(3, 2);
        let labels = Labels::Single { labels: vec![0, 1, 0], n_classes: 2 };
        Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)], feats, labels)
    }

    #[test]
    fn from_edges_symmetrizes_and_dedups() {
        let feats = Mat::zeros(3, 1);
        let labels = Labels::Single { labels: vec![0; 3], n_classes: 1 };
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)], feats, labels);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn mean_propagation_rows_sum_to_one() {
        let g = triangle();
        let p = g.mean_propagation_matrix();
        for r in 0..3 {
            let s: f32 = p.row_entries(r).map(|(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn gcn_propagation_symmetric_weights() {
        let g = triangle();
        let p = g.propagation_matrix().to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((p.get(i, j) - p.get(j, i)).abs() < 1e-6);
            }
        }
        // triangle: all degrees 2, d̃=3 → every weight 1/3
        assert!((p.get(0, 1) - 1.0 / 3.0).abs() < 1e-6);
        assert!((p.get(0, 0) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn split_partitions_nodes() {
        let mut g = triangle();
        let mut rng = Rng::new(1);
        g.random_split(0.34, 0.33, &mut rng);
        let total = g.train_mask.len() + g.val_mask.len() + g.test_mask.len();
        assert_eq!(total, 3);
        let mut all: Vec<u32> =
            g.train_mask.iter().chain(&g.val_mask).chain(&g.test_mask).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn topology_matches_graph_adjacency() {
        let edges = [(0u32, 1u32), (1, 0), (0, 1), (1, 2), (3, 3)];
        let feats = Mat::zeros(4, 1);
        let labels = Labels::Single { labels: vec![0; 4], n_classes: 1 };
        let g = Graph::from_edges(4, &edges, feats, labels);
        let t = Topology::from_edges(4, &edges);
        assert_eq!(t.indptr, g.indptr);
        assert_eq!(t.indices, g.indices);
        assert_eq!(t.adj().neighbors(1), g.adj().neighbors(1));
        assert_eq!(t.num_edges(), g.num_edges());
    }

    #[test]
    fn split_ids_matches_random_split() {
        let mut g = triangle();
        g.random_split(0.34, 0.33, &mut Rng::new(4));
        let (tr, va, te) = split_ids(3, 0.34, 0.33, &mut Rng::new(4));
        assert_eq!(tr, g.train_mask);
        assert_eq!(va, g.val_mask);
        assert_eq!(te, g.test_mask);
    }

    #[test]
    fn validate_catches_asymmetry() {
        let mut g = triangle();
        g.indices = vec![2, 0, 2, 0, 1];
        g.indptr = vec![0, 1, 3, 5];
        assert!(g.validate().is_err());
    }
}
