//! Graph substrate: the in-memory graph type, synthetic dataset
//! generators, feature/label synthesis, GCN normalization, binary IO,
//! and the dataset presets that mirror the paper's four benchmarks.

pub mod generate;
pub mod features;
pub mod io;
pub mod presets;

use crate::tensor::{Csr, Mat};

/// Node labels: single-label classification (Reddit/ogbn-products style)
/// or multi-label (Yelp style).
#[derive(Clone, Debug, PartialEq)]
pub enum Labels {
    /// `labels[v] ∈ [0, n_classes)`
    Single { labels: Vec<u32>, n_classes: usize },
    /// rows×classes {0,1} indicator matrix
    Multi { targets: Mat },
}

impl Labels {
    pub fn n_classes(&self) -> usize {
        match self {
            Labels::Single { n_classes, .. } => *n_classes,
            Labels::Multi { targets } => targets.cols,
        }
    }

    pub fn is_multilabel(&self) -> bool {
        matches!(self, Labels::Multi { .. })
    }
}

/// An undirected graph in CSR adjacency form with node features, labels,
/// and train/val/test splits (sorted node-id lists).
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// CSR adjacency: `indptr.len() == n+1`; neighbor lists sorted,
    /// both directions present, no self-loops stored (the GCN
    /// normalization adds Ã = A + I itself).
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub features: Mat,
    pub labels: Labels,
    pub train_mask: Vec<u32>,
    pub val_mask: Vec<u32>,
    pub test_mask: Vec<u32>,
}

impl Graph {
    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v]..self.indptr[v + 1]]
    }

    pub fn feat_dim(&self) -> usize {
        self.features.cols
    }

    /// Build CSR adjacency from an undirected edge list (u, v), u != v.
    /// Deduplicates and symmetrizes.
    pub fn from_edges(n: usize, edges: &[(u32, u32)], features: Mat, labels: Labels) -> Graph {
        assert_eq!(features.rows, n);
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(edges.len() * 2);
        for &(u, v) in edges {
            debug_assert!((u as usize) < n && (v as usize) < n);
            if u == v {
                continue;
            }
            pairs.push((u, v));
            pairs.push((v, u));
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut indptr = vec![0usize; n + 1];
        let mut indices = Vec::with_capacity(pairs.len());
        for &(u, v) in &pairs {
            indptr[u as usize + 1] += 1;
            indices.push(v);
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        Graph {
            n,
            indptr,
            indices,
            features,
            labels,
            train_mask: Vec::new(),
            val_mask: Vec::new(),
            test_mask: Vec::new(),
        }
    }

    /// Normalized degree vector `d̃_v = deg(v) + 1` (Ã = A + I).
    pub fn degrees_tilde(&self) -> Vec<f32> {
        (0..self.n).map(|v| (self.degree(v) + 1) as f32).collect()
    }

    /// GCN propagation matrix `P = D̃^{-1/2} Ã D̃^{-1/2}` with `Ã = A + I`
    /// over the **full** graph (reference semantics; the partitioned
    /// equivalent is assembled per-partition by `coordinator::halo`).
    pub fn propagation_matrix(&self) -> Csr {
        let deg_t = self.degrees_tilde();
        let mut trip = Vec::with_capacity(self.indices.len() + self.n);
        for v in 0..self.n {
            let dv = deg_t[v];
            // self-loop weight 1/d̃_v = 1/(√d̃_v·√d̃_v)
            trip.push((v as u32, v as u32, 1.0 / dv));
            for &u in self.neighbors(v) {
                trip.push((v as u32, u, 1.0 / (dv.sqrt() * deg_t[u as usize].sqrt())));
            }
        }
        Csr::from_triplets(self.n, self.n, trip)
    }

    /// Mean-aggregator propagation `P = D̃^{-1} Ã` (GraphSAGE-mean as in
    /// Eq. 3 of the paper, including the node itself).
    pub fn mean_propagation_matrix(&self) -> Csr {
        let mut trip = Vec::with_capacity(self.indices.len() + self.n);
        for v in 0..self.n {
            let inv = 1.0 / (self.degree(v) + 1) as f32;
            trip.push((v as u32, v as u32, inv));
            for &u in self.neighbors(v) {
                trip.push((v as u32, u, inv));
            }
        }
        Csr::from_triplets(self.n, self.n, trip)
    }

    /// Random train/val/test split with the given fractions.
    pub fn random_split(&mut self, train_frac: f64, val_frac: f64, rng: &mut crate::util::rng::Rng) {
        let mut ids: Vec<u32> = (0..self.n as u32).collect();
        rng.shuffle(&mut ids);
        let n_train = (self.n as f64 * train_frac).round() as usize;
        let n_val = (self.n as f64 * val_frac).round() as usize;
        self.train_mask = ids[..n_train].to_vec();
        self.val_mask = ids[n_train..(n_train + n_val).min(self.n)].to_vec();
        self.test_mask = ids[(n_train + n_val).min(self.n)..].to_vec();
        self.train_mask.sort_unstable();
        self.val_mask.sort_unstable();
        self.test_mask.sort_unstable();
    }

    /// Sanity invariants (used by tests and after IO round-trips).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.n + 1 {
            return Err("indptr length".into());
        }
        if *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr tail".into());
        }
        for v in 0..self.n {
            let nb = self.neighbors(v);
            if !nb.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("neighbors of {v} not sorted/unique"));
            }
            for &u in nb {
                if u as usize >= self.n {
                    return Err("neighbor out of range".into());
                }
                if u as usize == v {
                    return Err("self loop stored".into());
                }
                if self.neighbors(u as usize).binary_search(&(v as u32)).is_err() {
                    return Err(format!("edge {v}->{u} not symmetric"));
                }
            }
        }
        if self.features.rows != self.n {
            return Err("features rows".into());
        }
        match &self.labels {
            Labels::Single { labels, n_classes } => {
                if labels.len() != self.n {
                    return Err("labels len".into());
                }
                if labels.iter().any(|&l| l as usize >= *n_classes) {
                    return Err("label out of range".into());
                }
            }
            Labels::Multi { targets } => {
                if targets.rows != self.n {
                    return Err("targets rows".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn triangle() -> Graph {
        let feats = Mat::zeros(3, 2);
        let labels = Labels::Single { labels: vec![0, 1, 0], n_classes: 2 };
        Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)], feats, labels)
    }

    #[test]
    fn from_edges_symmetrizes_and_dedups() {
        let feats = Mat::zeros(3, 1);
        let labels = Labels::Single { labels: vec![0; 3], n_classes: 1 };
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)], feats, labels);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn mean_propagation_rows_sum_to_one() {
        let g = triangle();
        let p = g.mean_propagation_matrix();
        for r in 0..3 {
            let s: f32 = p.row_entries(r).map(|(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn gcn_propagation_symmetric_weights() {
        let g = triangle();
        let p = g.propagation_matrix().to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((p.get(i, j) - p.get(j, i)).abs() < 1e-6);
            }
        }
        // triangle: all degrees 2, d̃=3 → every weight 1/3
        assert!((p.get(0, 1) - 1.0 / 3.0).abs() < 1e-6);
        assert!((p.get(0, 0) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn split_partitions_nodes() {
        let mut g = triangle();
        let mut rng = Rng::new(1);
        g.random_split(0.34, 0.33, &mut rng);
        let total = g.train_mask.len() + g.val_mask.len() + g.test_mask.len();
        assert_eq!(total, 3);
        let mut all: Vec<u32> =
            g.train_mask.iter().chain(&g.val_mask).chain(&g.test_mask).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn validate_catches_asymmetry() {
        let mut g = triangle();
        g.indices = vec![2, 0, 2, 0, 1];
        g.indptr = vec![0, 1, 3, 5];
        assert!(g.validate().is_err());
    }
}
