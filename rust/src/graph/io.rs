//! Binary graph serialization (little-endian, versioned).
//!
//! Format v1:
//! ```text
//! magic   u64   0x504950454743_4E31  ("PIPEGCN1")
//! n       u64
//! nnz     u64   (directed entries = indices.len())
//! fdim    u64
//! ltype   u64   0 = single (then n_classes u64), 1 = multi (then classes u64)
//! indptr  (n+1)×u64
//! indices nnz×u32
//! feats   n*fdim×f32
//! labels  single: n×u32 | multi: n*classes×f32
//! masks   3 × (len u64, ids len×u32)
//! ```

use super::{Graph, Labels};
use crate::tensor::Mat;
use std::io::{self, Read, Write};

const MAGIC: u64 = 0x5049_5045_4743_4E31;

fn w_u64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_u32s(w: &mut impl Write, vs: &[u32]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(vs.len() * 4);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_u32s(r: &mut impl Read, n: usize) -> io::Result<Vec<u32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn w_f32s(w: &mut impl Write, vs: &[f32]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(vs.len() * 4);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

pub fn write_graph(g: &Graph, w: &mut impl Write) -> io::Result<()> {
    w_u64(w, MAGIC)?;
    w_u64(w, g.n as u64)?;
    w_u64(w, g.indices.len() as u64)?;
    w_u64(w, g.features.cols as u64)?;
    match &g.labels {
        Labels::Single { n_classes, .. } => {
            w_u64(w, 0)?;
            w_u64(w, *n_classes as u64)?;
        }
        Labels::Multi { targets } => {
            w_u64(w, 1)?;
            w_u64(w, targets.cols as u64)?;
        }
    }
    let indptr64: Vec<u8> = g.indptr.iter().flat_map(|&v| (v as u64).to_le_bytes()).collect();
    w.write_all(&indptr64)?;
    w_u32s(w, &g.indices)?;
    w_f32s(w, &g.features.data)?;
    match &g.labels {
        Labels::Single { labels, .. } => w_u32s(w, labels)?,
        Labels::Multi { targets } => w_f32s(w, &targets.data)?,
    }
    for mask in [&g.train_mask, &g.val_mask, &g.test_mask] {
        w_u64(w, mask.len() as u64)?;
        w_u32s(w, mask)?;
    }
    Ok(())
}

pub fn read_graph(r: &mut impl Read) -> io::Result<Graph> {
    let magic = r_u64(r)?;
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = r_u64(r)? as usize;
    let nnz = r_u64(r)? as usize;
    let fdim = r_u64(r)? as usize;
    let ltype = r_u64(r)?;
    let classes = r_u64(r)? as usize;
    let mut indptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        indptr.push(r_u64(r)? as usize);
    }
    let indices = r_u32s(r, nnz)?;
    let features = Mat::from_vec(n, fdim, r_f32s(r, n * fdim)?);
    let labels = if ltype == 0 {
        Labels::Single { labels: r_u32s(r, n)?, n_classes: classes }
    } else {
        Labels::Multi { targets: Mat::from_vec(n, classes, r_f32s(r, n * classes)?) }
    };
    let mut masks = Vec::new();
    for _ in 0..3 {
        let len = r_u64(r)? as usize;
        masks.push(r_u32s(r, len)?);
    }
    let test_mask = masks.pop().unwrap();
    let val_mask = masks.pop().unwrap();
    let train_mask = masks.pop().unwrap();
    Ok(Graph { n, indptr, indices, features, labels, train_mask, val_mask, test_mask })
}

pub fn save(g: &Graph, path: &str) -> io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_graph(g, &mut f)
}

pub fn load(path: &str) -> io::Result<Graph> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_graph(&mut f)
}

/// Append rows to a CSV file (creates + header if absent). Used by the
/// convergence-curve benches.
pub fn append_csv(path: &str, header: &str, rows: &[String]) -> io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let exists = std::path::Path::new(path).exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    if !exists {
        writeln!(f, "{header}")?;
    }
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm_dataset, SbmConfig};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_single_label() {
        let mut rng = Rng::new(1);
        let cfg = SbmConfig::new(120, 4, 5.0, 1.0);
        let g = sbm_dataset(&cfg, 8, 4, false, 0.3, &mut rng);
        let mut buf = Vec::new();
        write_graph(&g, &mut buf).unwrap();
        let g2 = read_graph(&mut buf.as_slice()).unwrap();
        g2.validate().unwrap();
        assert_eq!(g.n, g2.n);
        assert_eq!(g.indptr, g2.indptr);
        assert_eq!(g.indices, g2.indices);
        assert_eq!(g.features, g2.features);
        assert_eq!(g.labels, g2.labels);
        assert_eq!(g.train_mask, g2.train_mask);
        assert_eq!(g.test_mask, g2.test_mask);
    }

    #[test]
    fn roundtrip_multilabel() {
        let mut rng = Rng::new(2);
        let cfg = SbmConfig::new(60, 3, 4.0, 1.0);
        let g = sbm_dataset(&cfg, 8, 3, true, 0.3, &mut rng);
        let mut buf = Vec::new();
        write_graph(&g, &mut buf).unwrap();
        let g2 = read_graph(&mut buf.as_slice()).unwrap();
        assert_eq!(g.labels, g2.labels);
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = vec![0u8; 64];
        assert!(read_graph(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(3);
        let cfg = SbmConfig::new(40, 2, 3.0, 0.5);
        let g = sbm_dataset(&cfg, 4, 2, false, 0.3, &mut rng);
        let path = "/tmp/pipegcn_test_graph.bin";
        save(&g, path).unwrap();
        let g2 = load(path).unwrap();
        assert_eq!(g.indices, g2.indices);
        std::fs::remove_file(path).ok();
    }
}
