//! Synthetic graph generators.
//!
//! The paper evaluates on Reddit, ogbn-products, Yelp, and
//! ogbn-papers100M — none downloadable here — so the presets
//! (see [`super::presets`]) instantiate scaled **stochastic block model**
//! graphs whose community structure supplies learnable labels, plus
//! power-law (Barabási–Albert), Erdős–Rényi, and grid generators for
//! partitioner and scaling studies.

use super::{Graph, Labels, Topology};
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Stochastic block model parameters.
#[derive(Clone, Debug)]
pub struct SbmConfig {
    pub n: usize,
    pub communities: usize,
    /// expected intra-community degree per node
    pub intra_degree: f64,
    /// expected inter-community degree per node
    pub inter_degree: f64,
    /// cross-community locality: each community connects only to its
    /// `inter_span` nearest ring neighbors (0 = uniform over all pairs).
    /// Small spans mirror locally-clustered graphs (ogbn-products, Yelp)
    /// where METIS achieves low replication; 0 mirrors densely mixed
    /// graphs (Reddit).
    pub inter_span: usize,
    /// fraction of each community's nodes eligible as cross-community
    /// edge endpoints ("gateways"); controls boundary-node fraction and
    /// therefore METIS replication
    pub gateway_frac: f64,
}

impl SbmConfig {
    /// Uniform cross-community mixing (`inter_span = 0`).
    pub fn new(n: usize, communities: usize, intra_degree: f64, inter_degree: f64) -> Self {
        SbmConfig {
            n,
            communities,
            intra_degree,
            inter_degree,
            inter_span: 0,
            gateway_frac: 0.35,
        }
    }
}

/// Sample an SBM edge list. Communities are assigned round-robin so they
/// are balanced; edge counts are drawn from the expected-degree model
/// (sample `m` random pairs within/between blocks).
///
/// Returns `(edges, community)`.
pub fn sbm_edges(cfg: &SbmConfig, rng: &mut Rng) -> (Vec<(u32, u32)>, Vec<u32>) {
    sbm_edges_filtered(cfg, rng, None)
}

/// [`sbm_edges`] with edge storage restricted to edges touching a kept
/// node. The RNG stream (community shuffle + every pair draw) is
/// consumed exactly as in the unfiltered call, so the kept edges are
/// bit-identical to the matching edges of the monolithic build.
pub fn sbm_edges_filtered(
    cfg: &SbmConfig,
    rng: &mut Rng,
    keep: Option<&[bool]>,
) -> (Vec<(u32, u32)>, Vec<u32>) {
    let want = |a: u32, b: u32| keep.is_none_or(|k| k[a as usize] || k[b as usize]);
    let n = cfg.n;
    let k = cfg.communities.max(1);
    // Balanced community sizes, randomly assigned to node ids — otherwise
    // trivial id-based partitioners (hash/range) would accidentally align
    // with the community structure, which no real dataset exhibits.
    let mut community: Vec<u32> = (0..n).map(|v| (v % k) as u32).collect();
    rng.shuffle(&mut community);
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &c) in community.iter().enumerate() {
        members[c as usize].push(v as u32);
    }
    let mut edges = Vec::new();
    // intra-community edges: n * intra_degree / 2 total, spread per block
    for block in &members {
        let nb = block.len();
        if nb < 2 {
            continue;
        }
        let m = (nb as f64 * cfg.intra_degree / 2.0).round() as usize;
        for _ in 0..m {
            let a = block[rng.gen_range(nb)];
            let b = block[rng.gen_range(nb)];
            if a != b && want(a, b) {
                edges.push((a, b));
            }
        }
    }
    // Inter-community edges: endpoints drawn from each community's
    // "gateway" subset only. Real graphs route cross-cluster connectivity
    // through a minority of hub nodes — this is what keeps METIS boundary
    // replication near ~1.3 at small partition counts (paper Table 2
    // regime); uniform endpoints would make nearly every node a boundary
    // node.
    let gateway_frac = cfg.gateway_frac;
    let m_inter = (n as f64 * cfg.inter_degree / 2.0).round() as usize;
    let span = if cfg.inter_span == 0 { k - 1 } else { cfg.inter_span.min(k - 1) };
    if k > 1 {
        for _ in 0..m_inter {
            let ca = rng.gen_range(k);
            // ring-local target community within ±span of ca
            let off = 1 + rng.gen_range(span);
            let cb = if rng.bernoulli(0.5) { (ca + off) % k } else { (ca + k - off % k) % k };
            if cb == ca {
                continue;
            }
            if members[ca].is_empty() || members[cb].is_empty() {
                continue;
            }
            let gw = |len: usize| ((len as f64 * gateway_frac).ceil() as usize).max(1);
            let a = members[ca][rng.gen_range(gw(members[ca].len()))];
            let b = members[cb][rng.gen_range(gw(members[cb].len()))];
            if want(a, b) {
                edges.push((a, b));
            }
        }
    }
    (edges, community)
}

/// Erdős–Rényi G(n, m) with `m = n*avg_degree/2` sampled pairs.
pub fn erdos_renyi_edges(n: usize, avg_degree: f64, rng: &mut Rng) -> Vec<(u32, u32)> {
    let m = (n as f64 * avg_degree / 2.0).round() as usize;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let a = rng.gen_range(n) as u32;
        let b = rng.gen_range(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    edges
}

/// Barabási–Albert preferential attachment: each new node attaches to
/// `m` existing nodes chosen ∝ degree (implemented with the repeated-
/// endpoint trick: sample uniformly from the flat endpoint list).
pub fn barabasi_albert_edges(n: usize, m: usize, rng: &mut Rng) -> Vec<(u32, u32)> {
    assert!(m >= 1 && n > m);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m);
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m);
    // seed clique over the first m+1 nodes
    for a in 0..=m as u32 {
        for b in 0..a {
            edges.push((a, b));
            endpoints.push(a);
            endpoints.push(b);
        }
    }
    for v in (m + 1)..n {
        let mut targets = std::collections::HashSet::with_capacity(m * 2);
        while targets.len() < m {
            let t = endpoints[rng.gen_range(endpoints.len())];
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((v as u32, t));
            endpoints.push(v as u32);
            endpoints.push(t);
        }
    }
    edges
}

/// w×h 4-neighbor grid (useful partitioner sanity case: known optimal cuts).
pub fn grid2d_edges(w: usize, h: usize) -> Vec<(u32, u32)> {
    let mut edges = Vec::with_capacity(2 * w * h);
    let id = |x: usize, y: usize| (y * w + x) as u32;
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                edges.push((id(x, y), id(x + 1, y)));
            }
            if y + 1 < h {
                edges.push((id(x, y), id(x, y + 1)));
            }
        }
    }
    edges
}

/// Full SBM dataset: graph + class-conditioned features + labels + split.
/// This is the workhorse behind the presets.
pub fn sbm_dataset(
    cfg: &SbmConfig,
    feat_dim: usize,
    n_classes: usize,
    multilabel: bool,
    feature_noise: f32,
    rng: &mut Rng,
) -> Graph {
    let (edges, community) = sbm_edges(cfg, rng);
    let labels = super::features::labels_from_communities(
        &community,
        n_classes,
        multilabel,
        rng,
    );
    let features =
        super::features::class_features(&labels, &community, feat_dim, feature_noise, rng);
    let mut g = Graph::from_edges(cfg.n, &edges, features, labels);
    g.random_split(0.6, 0.2, rng);
    g
}

/// Adjacency-only SBM build: the full edge structure without features,
/// labels, or splits — the per-rank "degree/edge summary" of the scale
/// path. Bit-identical structure to [`sbm_dataset`] at the same seed
/// (it replays the same leading RNG draws).
pub fn sbm_topology(cfg: &SbmConfig, rng: &mut Rng) -> Topology {
    let (edges, _community) = sbm_edges(cfg, rng);
    Topology::from_edges(cfg.n, &edges)
}

/// One partition's slice of the dataset [`sbm_dataset`] (plus split and
/// test-shift) would build: features/labels/masks for owned nodes only,
/// plus the raw sampled edges touching an owned node. Built by replaying
/// the monolithic RNG stream with storage filtered, so every kept byte
/// is bit-identical to the monolithic build at the same seed —
/// independent of which rank builds which shard.
#[derive(Clone, Debug)]
pub struct Shard {
    /// global node count of the full dataset
    pub n: usize,
    /// global ids this shard owns, ascending
    pub owned: Vec<u32>,
    /// raw sampled edges with ≥1 owned endpoint (pre-symmetrize/dedup;
    /// the shard-concatenation property test reassembles the global
    /// edge set from these)
    pub edges: Vec<(u32, u32)>,
    /// owned-node features (`owned.len() × feat_dim`), rows in `owned` order
    pub features: Mat,
    /// owned-node labels, rows in `owned` order
    pub labels: Labels,
    /// global ids of owned train/val/test nodes, ascending
    pub train_mask: Vec<u32>,
    pub val_mask: Vec<u32>,
    pub test_mask: Vec<u32>,
    /// global #train nodes (loss normalization needs the global count)
    pub total_train: usize,
}

impl Shard {
    pub fn n_owned(&self) -> usize {
        self.owned.len()
    }

    pub fn feat_dim(&self) -> usize {
        self.features.cols
    }
}

/// Build the `part` shard of the dataset that
/// `sbm_dataset(cfg, ..) + random_split(0.6, 0.2) + test-shift` would
/// produce, holding only owned-node storage. `assign[v]` names the
/// owning partition of node `v` — any deterministic assignment works
/// (workers derive it by partitioning the shared [`sbm_topology`]), and
/// the output depends only on `(cfg, seed, assign, part)`, never on
/// which rank runs the build.
#[allow(clippy::too_many_arguments)]
pub fn sbm_shard(
    cfg: &SbmConfig,
    feat_dim: usize,
    n_classes: usize,
    multilabel: bool,
    feature_noise: f32,
    test_shift: f32,
    rng: &mut Rng,
    assign: &[u32],
    part: u32,
) -> Shard {
    assert_eq!(assign.len(), cfg.n);
    let keep: Vec<bool> = assign.iter().map(|&p| p == part).collect();
    let owned: Vec<u32> = (0..cfg.n as u32).filter(|&v| keep[v as usize]).collect();
    let (edges, community) = sbm_edges_filtered(cfg, rng, Some(&keep));
    let labels =
        super::features::labels_filtered(&community, n_classes, multilabel, rng, Some(&keep));
    let mut features = super::features::class_features_filtered(
        &labels,
        &community,
        feat_dim,
        feature_noise,
        rng,
        Some(&keep),
    );
    // replay of `random_split(0.6, 0.2)` — same shuffle, filtered storage
    let (train, val, test) = super::split_ids(cfg.n, 0.6, 0.2, rng);
    // replay of the preset test-shift: every test node draws its
    // feat_dim normals (ascending id order); only owned rows are stored
    if test_shift > 0.0 {
        for &v in &test {
            if keep[v as usize] {
                let r = owned.binary_search(&v).unwrap();
                for x in features.row_mut(r).iter_mut() {
                    *x += test_shift * rng.normal();
                }
            } else {
                for _ in 0..feat_dim {
                    rng.normal();
                }
            }
        }
    }
    let filter = |m: Vec<u32>| -> Vec<u32> {
        m.into_iter().filter(|&v| keep[v as usize]).collect()
    };
    let total_train = train.len();
    Shard {
        n: cfg.n,
        owned,
        edges,
        features,
        labels,
        train_mask: filter(train),
        val_mask: filter(val),
        test_mask: filter(test),
        total_train,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Labels;
    use crate::tensor::Mat;

    #[test]
    fn sbm_degrees_near_target() {
        let mut rng = Rng::new(1);
        let cfg = SbmConfig::new(2000, 8, 8.0, 2.0);
        let (edges, comm) = sbm_edges(&cfg, &mut rng);
        let feats = Mat::zeros(cfg.n, 1);
        let labels = Labels::Single { labels: comm.clone(), n_classes: 8 };
        let g = Graph::from_edges(cfg.n, &edges, feats, labels);
        g.validate().unwrap();
        let avg = 2.0 * g.num_edges() as f64 / g.n as f64;
        // duplicates get deduped so realized degree is a bit under 10
        assert!(avg > 6.0 && avg < 10.5, "avg degree {avg}");
        // homophily: most edges intra-community
        let mut intra = 0usize;
        for v in 0..g.n {
            for &u in g.neighbors(v) {
                if comm[v] == comm[u as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / g.indices.len() as f64;
        assert!(frac > 0.7, "intra fraction {frac}");
    }

    #[test]
    fn ba_graph_power_law_ish() {
        let mut rng = Rng::new(2);
        let edges = barabasi_albert_edges(1000, 3, &mut rng);
        let feats = Mat::zeros(1000, 1);
        let labels = Labels::Single { labels: vec![0; 1000], n_classes: 1 };
        let g = Graph::from_edges(1000, &edges, feats, labels);
        g.validate().unwrap();
        let max_deg = (0..g.n).map(|v| g.degree(v)).max().unwrap();
        let avg = 2.0 * g.num_edges() as f64 / g.n as f64;
        assert!(max_deg as f64 > 5.0 * avg, "hub degree {max_deg} vs avg {avg}");
    }

    #[test]
    fn grid_has_expected_edges() {
        let edges = grid2d_edges(4, 3);
        assert_eq!(edges.len(), 3 * 3 + 4 * 2); // (w-1)*h + w*(h-1)
    }

    #[test]
    fn er_graph_valid() {
        let mut rng = Rng::new(3);
        let edges = erdos_renyi_edges(500, 6.0, &mut rng);
        let feats = Mat::zeros(500, 1);
        let labels = Labels::Single { labels: vec![0; 500], n_classes: 1 };
        let g = Graph::from_edges(500, &edges, feats, labels);
        g.validate().unwrap();
        let avg = 2.0 * g.num_edges() as f64 / g.n as f64;
        assert!(avg > 4.0 && avg < 7.0, "avg {avg}");
    }

    #[test]
    fn sbm_dataset_full() {
        let mut rng = Rng::new(4);
        let cfg = SbmConfig::new(600, 6, 6.0, 1.5);
        let g = sbm_dataset(&cfg, 16, 6, false, 0.5, &mut rng);
        g.validate().unwrap();
        assert_eq!(g.feat_dim(), 16);
        assert_eq!(g.labels.n_classes(), 6);
        assert!(!g.train_mask.is_empty() && !g.test_mask.is_empty());
    }

    #[test]
    fn generators_deterministic() {
        let cfg = SbmConfig::new(300, 4, 5.0, 1.0);
        let (e1, c1) = sbm_edges(&cfg, &mut Rng::new(7));
        let (e2, c2) = sbm_edges(&cfg, &mut Rng::new(7));
        assert_eq!(e1, e2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn sbm_topology_matches_dataset_structure() {
        let cfg = SbmConfig::new(300, 4, 5.0, 1.0);
        let g = sbm_dataset(&cfg, 4, 4, false, 0.1, &mut Rng::new(31));
        let t = sbm_topology(&cfg, &mut Rng::new(31));
        assert_eq!(t.indptr, g.indptr);
        assert_eq!(t.indices, g.indices);
    }

    /// Shard replay vs monolithic build: every stored byte of every
    /// shard must equal the matching slice of the monolithic dataset.
    fn check_shard_equivalence(multilabel: bool, test_shift: f32) {
        let cfg = SbmConfig::new(400, 5, 6.0, 1.5);
        let seed = 11;
        let mut rng = Rng::new(seed);
        let mut g = sbm_dataset(&cfg, 8, 5, multilabel, 0.4, &mut rng);
        if test_shift > 0.0 {
            // same continuation the presets apply after sbm_dataset
            for v in g.test_mask.clone() {
                for x in g.features.row_mut(v as usize).iter_mut() {
                    *x += test_shift * rng.normal();
                }
            }
        }
        let assign: Vec<u32> = (0..cfg.n as u32).map(|v| v % 3).collect();
        for part in 0..3u32 {
            let sh = sbm_shard(
                &cfg,
                8,
                5,
                multilabel,
                0.4,
                test_shift,
                &mut Rng::new(seed),
                &assign,
                part,
            );
            assert_eq!(sh.n, cfg.n);
            assert_eq!(sh.feat_dim(), 8);
            for (r, &v) in sh.owned.iter().enumerate() {
                assert_eq!(
                    sh.features.row(r),
                    g.features.row(v as usize),
                    "features of node {v} (part {part})"
                );
                match (&sh.labels, &g.labels) {
                    (Labels::Single { labels: a, .. }, Labels::Single { labels: b, .. }) => {
                        assert_eq!(a[r], b[v as usize], "label of node {v}");
                    }
                    (Labels::Multi { targets: a }, Labels::Multi { targets: b }) => {
                        assert_eq!(a.row(r), b.row(v as usize), "targets of node {v}");
                    }
                    _ => panic!("label kinds differ"),
                }
            }
            let filt = |m: &[u32]| -> Vec<u32> {
                m.iter().copied().filter(|&v| assign[v as usize] == part).collect()
            };
            assert_eq!(sh.train_mask, filt(&g.train_mask));
            assert_eq!(sh.val_mask, filt(&g.val_mask));
            assert_eq!(sh.test_mask, filt(&g.test_mask));
            assert_eq!(sh.total_train, g.train_mask.len());
        }
    }

    #[test]
    fn shard_matches_monolithic_single_label() {
        check_shard_equivalence(false, 0.0);
    }

    #[test]
    fn shard_matches_monolithic_multilabel_with_shift() {
        check_shard_equivalence(true, 1.1);
    }

    #[test]
    fn shard_edges_reassemble_global_edge_set() {
        let cfg = SbmConfig::new(300, 4, 5.0, 1.0);
        let (edges, _c) = sbm_edges(&cfg, &mut Rng::new(21));
        let norm = |e: &[(u32, u32)]| -> std::collections::BTreeSet<(u32, u32)> {
            e.iter()
                .filter(|&&(a, b)| a != b)
                .map(|&(a, b)| if a <= b { (a, b) } else { (b, a) })
                .collect()
        };
        let assign: Vec<u32> =
            (0..cfg.n as u32).map(|v| v.wrapping_mul(2654435761) % 4).collect();
        let mut union = std::collections::BTreeSet::new();
        for part in 0..4u32 {
            let sh = sbm_shard(&cfg, 4, 4, false, 0.1, 0.0, &mut Rng::new(21), &assign, part);
            union.extend(norm(&sh.edges));
        }
        assert_eq!(union, norm(&edges));
    }
}
