//! Feature and label synthesis for the SBM datasets.
//!
//! Labels derive from communities (with controllable label noise for
//! single-label, and prototype mixtures for multi-label), features are
//! class-conditioned Gaussians — enough signal that a GCN materially
//! beats an MLP-on-features, which is the regime where boundary-feature
//! staleness actually matters.

use super::Labels;
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Map communities to labels.
///
/// Single-label: `label = community % n_classes`, with 5% label noise.
/// Multi-label: each node gets its community prototype class plus each
/// other class independently with prob 0.1 (Yelp-like sparse targets).
pub fn labels_from_communities(
    community: &[u32],
    n_classes: usize,
    multilabel: bool,
    rng: &mut Rng,
) -> Labels {
    labels_filtered(community, n_classes, multilabel, rng, None)
}

/// [`labels_from_communities`] with storage restricted to the nodes
/// where `keep` is true (rows in ascending node order). The RNG stream
/// is consumed for **every** node regardless, so the kept rows are
/// bit-identical to the matching rows of the unfiltered call — this is
/// what lets a shard build replay the monolithic stream.
pub fn labels_filtered(
    community: &[u32],
    n_classes: usize,
    multilabel: bool,
    rng: &mut Rng,
    keep: Option<&[bool]>,
) -> Labels {
    let kept = |v: usize| keep.is_none_or(|k| k[v]);
    if !multilabel {
        let mut labels = Vec::new();
        for (v, &c) in community.iter().enumerate() {
            let l = if rng.bernoulli(0.05) {
                rng.gen_range(n_classes) as u32
            } else {
                c % n_classes as u32
            };
            if kept(v) {
                labels.push(l);
            }
        }
        Labels::Single { labels, n_classes }
    } else {
        let n_keep = match keep {
            Some(k) => k.iter().filter(|&&b| b).count(),
            None => community.len(),
        };
        let mut targets = Mat::zeros(n_keep, n_classes);
        let mut row = 0usize;
        for (v, &c) in community.iter().enumerate() {
            let store = kept(v);
            if store {
                targets.set(row, (c as usize) % n_classes, 1.0);
            }
            for k in 0..n_classes {
                // the draw happens for every node; only kept rows land
                if rng.bernoulli(0.1) && store {
                    targets.set(row, k, 1.0);
                }
            }
            if store {
                row += 1;
            }
        }
        Labels::Multi { targets }
    }
}

/// Class prototypes: deterministic ±1 sign patterns scaled by `sep`,
/// then per-node Gaussian noise. Community (not just label) contributes
/// a secondary prototype so features carry graph structure even under
/// label noise.
pub fn class_features(
    labels: &Labels,
    community: &[u32],
    feat_dim: usize,
    noise: f32,
    rng: &mut Rng,
) -> Mat {
    class_features_filtered(labels, community, feat_dim, noise, rng, None)
}

/// [`class_features`] with storage restricted to the nodes where `keep`
/// is true. When `keep` is Some, `labels` must hold rows for the kept
/// nodes only (ascending node order) — i.e. the output of
/// [`labels_filtered`] with the same mask. Unkept nodes still draw
/// their `feat_dim` noise normals and discard them (the prototype math
/// is RNG-free), keeping the stream aligned with the monolithic call.
pub fn class_features_filtered(
    labels: &Labels,
    community: &[u32],
    feat_dim: usize,
    noise: f32,
    rng: &mut Rng,
    keep: Option<&[bool]>,
) -> Mat {
    let n = community.len();
    let n_classes = labels.n_classes();
    // prototype bank: one per class and one per community id bucket
    let proto = |id: usize, salt: u64| -> Vec<f32> {
        let mut s = (id as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15) ^ salt;
        (0..feat_dim)
            .map(|_| {
                if crate::util::rng::splitmix64(&mut s) & 1 == 0 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect()
    };
    let class_protos: Vec<Vec<f32>> = (0..n_classes).map(|c| proto(c, 0xA5)).collect();
    let kept = |v: usize| keep.is_none_or(|k| k[v]);
    let n_keep = match keep {
        Some(k) => k.iter().filter(|&&b| b).count(),
        None => n,
    };
    let mut out = Mat::zeros(n_keep, feat_dim);
    let mut r_idx = 0usize;
    for v in 0..n {
        if !kept(v) {
            // burn the noise draws so the stream matches the unfiltered call
            for _ in 0..feat_dim {
                rng.normal();
            }
            continue;
        }
        let lrow = r_idx;
        r_idx += 1;
        let row = out.row_mut(lrow);
        match labels {
            Labels::Single { labels, .. } => {
                let p = &class_protos[labels[lrow] as usize];
                for (r, &pv) in row.iter_mut().zip(p.iter()) {
                    *r += pv;
                }
            }
            Labels::Multi { targets } => {
                for c in 0..n_classes {
                    if targets.get(lrow, c) > 0.5 {
                        let p = &class_protos[c];
                        for (r, &pv) in row.iter_mut().zip(p.iter()) {
                            *r += 0.7 * pv;
                        }
                    }
                }
            }
        }
        // community prototype at lower amplitude
        let cp = proto(community[v] as usize, 0x5A);
        for (r, &pv) in row.iter_mut().zip(cp.iter()) {
            *r += 0.3 * pv;
        }
        for r in row.iter_mut() {
            *r += noise * rng.normal();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_labels_mostly_match_community() {
        let mut rng = Rng::new(1);
        let community: Vec<u32> = (0..1000).map(|v| (v % 4) as u32).collect();
        let labels = labels_from_communities(&community, 4, false, &mut rng);
        if let Labels::Single { labels, .. } = labels {
            let matches =
                community.iter().zip(&labels).filter(|(c, l)| c == l).count();
            assert!(matches > 900, "matches {matches}");
        } else {
            panic!("expected single");
        }
    }

    #[test]
    fn multilabel_has_primary_class() {
        let mut rng = Rng::new(2);
        let community: Vec<u32> = (0..100).map(|v| (v % 3) as u32).collect();
        let labels = labels_from_communities(&community, 3, true, &mut rng);
        if let Labels::Multi { targets } = labels {
            for v in 0..100 {
                assert_eq!(targets.get(v, (community[v] as usize) % 3), 1.0);
            }
        } else {
            panic!("expected multi");
        }
    }

    #[test]
    fn features_separate_classes() {
        let mut rng = Rng::new(3);
        let community: Vec<u32> = (0..200).map(|v| (v % 2) as u32).collect();
        let labels = labels_from_communities(&community, 2, false, &mut rng);
        let feats = class_features(&labels, &community, 32, 0.1, &mut rng);
        // mean intra-class distance << inter-class distance
        let lab = match &labels {
            Labels::Single { labels, .. } => labels.clone(),
            _ => unreachable!(),
        };
        let dist = |a: usize, b: usize| -> f32 {
            feats
                .row(a)
                .iter()
                .zip(feats.row(b))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut ni = 0;
        let mut nx = 0;
        for a in 0..50 {
            for b in (a + 1)..50 {
                if lab[a] == lab[b] {
                    intra += dist(a, b);
                    ni += 1;
                } else {
                    inter += dist(a, b);
                    nx += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f32, inter / nx as f32);
        assert!(inter > 2.0 * intra, "intra {intra} inter {inter}");
    }

    #[test]
    fn features_deterministic_given_seed() {
        let community: Vec<u32> = (0..50).map(|v| (v % 2) as u32).collect();
        let l1 = labels_from_communities(&community, 2, false, &mut Rng::new(9));
        let l2 = labels_from_communities(&community, 2, false, &mut Rng::new(9));
        assert_eq!(l1, l2);
        let f1 = class_features(&l1, &community, 8, 0.2, &mut Rng::new(10));
        let f2 = class_features(&l2, &community, 8, 0.2, &mut Rng::new(10));
        assert_eq!(f1, f2);
    }
}
