//! Dataset + model presets mirroring the paper's Table 3, scaled to a
//! single-core testbed.
//!
//! | preset        | paper dataset    | paper n / deg / feat / model          | here |
//! |---------------|------------------|----------------------------------------|------|
//! | `reddit-sim`  | Reddit           | 233K / ~490 / 602 / 4×256, 41 cls     | 4K / ~48 / 128 / 4×64, 16 cls |
//! | `products-sim`| ogbn-products    | 2.4M / ~52 / 100 / 3×128, 47 cls      | 6K / ~20 / 96 / 3×64, 16 cls |
//! | `yelp-sim`    | Yelp             | 716K / ~20 / 300 / 4×512, 100 multi   | 3K / ~12 / 64 / 4×64, 12 multi |
//! | `papers-sim`  | ogbn-papers100M  | 111M / ~29 / 128 / 3×48, 172 cls      | 12K / ~16 / 64 / 3×48, 24 cls |
//! | `tiny`        | (tests/quickstart)| —                                     | 512 / ~10 / 32 / 2×32, 8 cls |
//! | `reddit-1m`   | Reddit (scale run)| 233K / ~490 / 602 / 4×256, 41 cls    | 1M / ~10 / 32 / 2×32, 16 cls |
//! | `papers-10m`  | ogbn-papers100M  | 111M / ~29 / 128 / 3×48, 172 cls      | 10M / ~7.5 / 32 / 2×32, 32 cls |
//!
//! The `reddit-1m`/`papers-10m` presets are the **scale trajectory**:
//! paper-scale node counts at trimmed degree/width so they train on a
//! laptop-class mesh through the sharded build path (`build_topology` +
//! `build_shard`) without any rank materializing the full graph.
//!
//! The *relative* quantities that drive PipeGCN's behaviour — boundary
//! fraction after partitioning, bytes per boundary node per layer, number
//! of layers — are preserved in spirit; absolute accuracy is dataset-
//! specific and not comparable. Simulated-throughput experiments rescale
//! per-device compute with the preset's `sim_scale` so comm:compute
//! ratios land near the paper's Table 2 (see `sim::profiles`).

use super::generate::{sbm_dataset, sbm_shard, sbm_topology, SbmConfig, Shard};
use super::{Graph, Topology};
use crate::util::rng::Rng;

/// The mirrored dataset's true scale (paper Table 3) — used by
/// `exp::full_works` to project measured partition structure onto the
/// full-size workload for the timeline simulator.
#[derive(Clone, Copy, Debug)]
pub struct FullScale {
    /// nodes
    pub n: f64,
    /// directed adjacency entries (≈ 2 × undirected edges)
    pub nnz: f64,
    /// input feature width
    pub feat: usize,
    /// hidden width
    pub hidden: usize,
    /// output classes
    pub classes: usize,
}

/// Everything needed to instantiate a dataset + its model + training
/// hyper-parameters (paper Table 3 analogue).
#[derive(Clone, Debug)]
pub struct Preset {
    pub name: &'static str,
    /// paper dataset this mirrors
    pub mirrors: &'static str,
    pub n: usize,
    pub communities: usize,
    pub intra_degree: f64,
    pub inter_degree: f64,
    pub feat_dim: usize,
    pub n_classes: usize,
    pub multilabel: bool,
    pub feature_noise: f32,
    /// model: #layers (GraphSAGE-mean) and hidden width
    pub layers: usize,
    pub hidden: usize,
    pub lr: f32,
    pub dropout: f32,
    pub epochs: usize,
    /// minimum #partitions the paper reports for this dataset
    pub min_parts: usize,
    /// scale factor applied to simulated tensor sizes (full-size rows ÷
    /// scaled rows) — coarse knob used outside the calibrated
    /// `exp::full_works` projection.
    pub sim_scale: f64,
    /// mirrored dataset's true scale (Table 3)
    pub full: FullScale,
    /// SBM cross-community locality (0 = uniform; see `SbmConfig`)
    pub inter_span: usize,
    /// SBM gateway-node fraction (see `SbmConfig::gateway_frac`)
    pub gateway_frac: f64,
    /// extra Gaussian feature noise added to TEST nodes only — models the
    /// train/test distribution shift the paper calls out for
    /// ogbn-products ("the distribution of its test set largely differs
    /// from that of its training set", §4.4), which is what makes the
    /// γ-overfitting effect of Fig. 6 observable
    pub test_shift: f32,
}

pub const PRESETS: [Preset; 7] = [
    Preset {
        name: "tiny",
        mirrors: "(tests)",
        n: 512,
        communities: 8,
        intra_degree: 8.0,
        inter_degree: 2.0,
        feat_dim: 32,
        n_classes: 8,
        multilabel: false,
        feature_noise: 0.8,
        layers: 2,
        hidden: 32,
        lr: 0.01,
        dropout: 0.0,
        epochs: 60,
        min_parts: 2,
        sim_scale: 1.0,
        full: FullScale { n: 512.0, nnz: 5200.0, feat: 32, hidden: 32, classes: 8 },
        inter_span: 0,
        gateway_frac: 0.35,
        test_shift: 0.0,
    },
    Preset {
        name: "reddit-sim",
        mirrors: "Reddit",
        n: 4000,
        communities: 16,
        intra_degree: 40.0,
        inter_degree: 8.0,
        feat_dim: 128,
        n_classes: 16,
        multilabel: false,
        feature_noise: 1.2,
        layers: 4,
        hidden: 64,
        lr: 0.01,
        dropout: 0.5,
        epochs: 120,
        min_parts: 2,
        sim_scale: 58.25, // 233K / 4K
        full: FullScale { n: 233_000.0, nnz: 114_000_000.0, feat: 602, hidden: 256, classes: 41 },
        inter_span: 0,
        gateway_frac: 0.35,
        test_shift: 0.0,
    },
    Preset {
        name: "products-sim",
        mirrors: "ogbn-products",
        n: 6000,
        communities: 30, // ≥3× max partition count so parts align with clusters
        intra_degree: 16.0,
        inter_degree: 1.6, // calibrated: replication ≈1.2 @ 5 parts (Table 2)
        feat_dim: 96,
        n_classes: 16,
        multilabel: false,
        feature_noise: 1.5,
        layers: 3,
        hidden: 64,
        lr: 0.003,
        dropout: 0.3,
        epochs: 100,
        min_parts: 5,
        sim_scale: 400.0, // 2.4M / 6K
        full: FullScale { n: 2_400_000.0, nnz: 124_000_000.0, feat: 100, hidden: 128, classes: 47 },
        inter_span: 2,
        gateway_frac: 0.1,
        test_shift: 1.1,
    },
    Preset {
        name: "yelp-sim",
        mirrors: "Yelp",
        n: 3000,
        communities: 18,
        intra_degree: 10.0,
        inter_degree: 0.9, // calibrated: replication ≈1.15 @ 3 parts (Table 2)
        feat_dim: 64,
        n_classes: 12,
        multilabel: true,
        feature_noise: 1.0,
        layers: 4,
        hidden: 64,
        lr: 0.001,
        dropout: 0.1,
        epochs: 100,
        min_parts: 3,
        sim_scale: 238.7, // 716K / 3K
        full: FullScale { n: 716_000.0, nnz: 14_000_000.0, feat: 300, hidden: 512, classes: 100 },
        inter_span: 2,
        gateway_frac: 0.12,
        test_shift: 0.0,
    },
    Preset {
        name: "papers-sim",
        mirrors: "ogbn-papers100M",
        n: 12000,
        communities: 96, // 3× the 32-partition setting of §4.5
        intra_degree: 12.0,
        inter_degree: 2.0,
        feat_dim: 64,
        n_classes: 24,
        multilabel: false,
        feature_noise: 1.5,
        layers: 3,
        hidden: 48,
        lr: 0.01,
        dropout: 0.0,
        epochs: 60,
        min_parts: 32,
        sim_scale: 9250.0, // 111M / 12K
        full: FullScale { n: 111_000_000.0, nnz: 3_200_000_000.0, feat: 128, hidden: 48, classes: 172 },
        inter_span: 3,
        gateway_frac: 0.15,
        test_shift: 0.0,
    },
    Preset {
        name: "reddit-1m",
        mirrors: "Reddit (scale run)",
        n: 1_000_000,
        communities: 2048,
        intra_degree: 8.0,
        inter_degree: 2.0,
        feat_dim: 32,
        n_classes: 16,
        multilabel: false,
        feature_noise: 1.0,
        layers: 2,
        hidden: 32,
        lr: 0.01,
        dropout: 0.0,
        epochs: 10,
        min_parts: 4,
        sim_scale: 1.0,
        full: FullScale { n: 1_000_000.0, nnz: 10_000_000.0, feat: 32, hidden: 32, classes: 16 },
        inter_span: 4,
        gateway_frac: 0.15,
        test_shift: 0.0,
    },
    Preset {
        name: "papers-10m",
        mirrors: "ogbn-papers100M (scale run)",
        n: 10_000_000,
        communities: 8192,
        intra_degree: 6.0,
        inter_degree: 1.5,
        feat_dim: 32,
        n_classes: 32,
        multilabel: false,
        feature_noise: 1.2,
        layers: 2,
        hidden: 32,
        lr: 0.01,
        dropout: 0.0,
        epochs: 5,
        min_parts: 8,
        sim_scale: 1.0,
        full: FullScale { n: 10_000_000.0, nnz: 75_000_000.0, feat: 32, hidden: 32, classes: 32 },
        inter_span: 4,
        gateway_frac: 0.15,
        test_shift: 0.0,
    },
];

pub fn by_name(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

pub fn names() -> Vec<&'static str> {
    PRESETS.iter().map(|p| p.name).collect()
}

impl Preset {
    /// SBM parameters at node count `n` (degree-aware: expected degrees
    /// stay fixed as `n` scales, like real-graph density).
    fn sbm_config(&self, n: usize) -> SbmConfig {
        SbmConfig {
            n,
            communities: self.communities,
            intra_degree: self.intra_degree,
            inter_degree: self.inter_degree,
            inter_span: self.inter_span,
            gateway_frac: self.gateway_frac,
        }
    }

    /// RNG for the build at node count `n`: `n == self.n` is the
    /// canonical stream (`build`), any other `n` is the scaled stream
    /// (`build_scaled`). Shard and topology builds replay these exact
    /// streams, so the seeding must never diverge between paths.
    fn build_rng(&self, n: usize, seed: u64) -> Rng {
        if n == self.n {
            Rng::new(seed ^ 0xDA7A5E7)
        } else {
            Rng::new(seed ^ 0xDA7A5E7 ^ (n as u64).rotate_left(17))
        }
    }

    /// Instantiate the dataset (deterministic in `seed`).
    pub fn build(&self, seed: u64) -> Graph {
        self.build_scaled(self.n, seed)
    }

    /// Instantiate at a different node count (scaling studies) keeping
    /// density and label structure.
    pub fn build_scaled(&self, n: usize, seed: u64) -> Graph {
        let mut rng = self.build_rng(n, seed);
        let cfg = self.sbm_config(n);
        let mut g = sbm_dataset(
            &cfg,
            self.feat_dim,
            self.n_classes,
            self.multilabel,
            self.feature_noise,
            &mut rng,
        );
        self.apply_test_shift(&mut g, &mut rng);
        g
    }

    /// Adjacency-only build: the structure [`Preset::build`] would
    /// produce, without features/labels/splits. This is what every rank
    /// of the scale path holds — enough for partitioning, global
    /// degrees, and halo assembly at a fraction of full-graph memory.
    pub fn build_topology(&self, seed: u64) -> Topology {
        self.build_topology_scaled(self.n, seed)
    }

    /// [`Preset::build_topology`] at node count `n`.
    pub fn build_topology_scaled(&self, n: usize, seed: u64) -> Topology {
        let mut rng = self.build_rng(n, seed);
        sbm_topology(&self.sbm_config(n), &mut rng)
    }

    /// One partition's shard of the dataset [`Preset::build`] would
    /// produce (features/labels/masks for owned nodes only) —
    /// bit-identical to the matching slice of the monolithic build,
    /// regardless of which rank builds it.
    pub fn build_shard(&self, seed: u64, assign: &[u32], part: u32) -> Shard {
        self.build_shard_scaled(self.n, seed, assign, part)
    }

    /// [`Preset::build_shard`] at node count `n`.
    pub fn build_shard_scaled(&self, n: usize, seed: u64, assign: &[u32], part: u32) -> Shard {
        let mut rng = self.build_rng(n, seed);
        sbm_shard(
            &self.sbm_config(n),
            self.feat_dim,
            self.n_classes,
            self.multilabel,
            self.feature_noise,
            self.test_shift,
            &mut rng,
            assign,
            part,
        )
    }
}

impl Preset {
    /// Perturb test-node features to model train/test distribution shift
    /// (no-op when `test_shift == 0`).
    fn apply_test_shift(&self, g: &mut Graph, rng: &mut Rng) {
        if self.test_shift <= 0.0 {
            return;
        }
        for &v in &g.test_mask.clone() {
            let row = g.features.row_mut(v as usize);
            for x in row.iter_mut() {
                *x += self.test_shift * rng.normal();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_buildable_tiny_scale() {
        for p in &PRESETS {
            // scale down so the test is fast; structure must stay valid
            let g = p.build_scaled(300.max(p.communities * 8), 1);
            g.validate().unwrap();
            assert_eq!(g.feat_dim(), p.feat_dim);
            assert_eq!(g.labels.n_classes(), p.n_classes);
            assert_eq!(g.labels.is_multilabel(), p.multilabel);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("reddit-sim").is_some());
        assert!(by_name("nope").is_none());
        assert_eq!(names().len(), PRESETS.len());
    }

    #[test]
    fn tiny_preset_builds_fast_and_learnable() {
        let p = by_name("tiny").unwrap();
        let g = p.build(42);
        g.validate().unwrap();
        assert_eq!(g.n, 512);
        let avg_deg = 2.0 * g.num_edges() as f64 / g.n as f64;
        assert!(avg_deg > 5.0, "avg degree {avg_deg}");
    }

    #[test]
    fn build_deterministic() {
        let p = by_name("tiny").unwrap();
        let a = p.build(7);
        let b = p.build(7);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn topology_and_shards_match_monolithic_build() {
        // products-sim exercises the test_shift replay path
        let p = by_name("products-sim").unwrap();
        let n = 480;
        let g = p.build_scaled(n, 3);
        let t = p.build_topology_scaled(n, 3);
        assert_eq!(t.indptr, g.indptr);
        assert_eq!(t.indices, g.indices);
        let assign: Vec<u32> = (0..n as u32).map(|v| v % 2).collect();
        for part in 0..2u32 {
            let sh = p.build_shard_scaled(n, 3, &assign, part);
            assert_eq!(sh.n, n);
            for (r, &v) in sh.owned.iter().enumerate() {
                assert_eq!(sh.features.row(r), g.features.row(v as usize), "node {v}");
            }
            assert_eq!(sh.total_train, g.train_mask.len());
        }
    }
}
