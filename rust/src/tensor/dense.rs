//! Dense row-major f32 matrix with cache-blocked GEMM variants.
//!
//! The hot path of GCN training is `P·H` (sparse, see [`super::sparse`])
//! followed by `(P·H)·W` (dense, here). The backward pass additionally
//! needs `AᵀB` (weight gradients) and `A·Bᵀ` (feature gradients), so all
//! three GEMM variants are provided with a k-blocked, write-streaming
//! loop order that autovectorizes on the inner `j` loop.
//!
//! Threading: the GEMMs dispatch to [`crate::runtime::pool`] over
//! disjoint **output-row blocks**. Each output element has one owner
//! task that accumulates in the same order as the serial kernel, so
//! results are bit-identical at any thread count (asserted in
//! `tests/parallel_kernels.rs`).

use crate::runtime::pool;
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Block size over the reduction dimension; 64×f32 = 256 B per panel row,
/// chosen so an A-panel row plus a C row fit comfortably in L1.
const KBLOCK: usize = 64;

/// Minimum multiply-add count (`m·k·n`) before a GEMM goes to the pool
/// — below this, job dispatch overhead dominates the kernel.
const PAR_GEMM_MIN: usize = 1 << 15;

/// One output row of `C = A·B`: `c_row = a_row·B`, k-blocked with a
/// 4-way unroll. Extracting the row kernel fixes the per-element f32
/// summation order (k ascending, 4-fused groups) that the serial and
/// row-parallel paths share, so they agree bit-for-bit. Crate-visible
/// so the serving tier's activation cache can recompute a row subset
/// bit-identically to a full [`Mat::matmul`].
pub(crate) fn gemm_row(a_row: &[f32], b: &Mat, c_row: &mut [f32]) {
    let n = b.cols;
    c_row.iter_mut().for_each(|x| *x = 0.0);
    for k0 in (0..a_row.len()).step_by(KBLOCK) {
        let k1 = (k0 + KBLOCK).min(a_row.len());
        let mut k = k0;
        while k + 4 <= k1 {
            let (a0, a1, a2, a3) = (a_row[k], a_row[k + 1], a_row[k + 2], a_row[k + 3]);
            if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                let b0 = &b.data[k * n..(k + 1) * n];
                let b1 = &b.data[(k + 1) * n..(k + 2) * n];
                let b2 = &b.data[(k + 2) * n..(k + 3) * n];
                let b3 = &b.data[(k + 3) * n..(k + 4) * n];
                for j in 0..n {
                    c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
            }
            k += 4;
        }
        while k < k1 {
            let aik = a_row[k];
            if aik != 0.0 {
                let b_row = &b.data[k * n..(k + 1) * n];
                for (cv, bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *cv += aik * *bv;
                }
            }
            k += 1;
        }
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() * std);
        }
        Mat { rows, cols, data }
    }

    /// Uniform(-a, a) entries (Glorot-style init).
    pub fn rand_uniform(rows: usize, cols: usize, a: f32, rng: &mut Rng) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push((rng.next_f32() * 2.0 - 1.0) * a);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Copy `src` into row `r`.
    pub fn set_row(&mut self, r: usize, src: &[f32]) {
        self.row_mut(r).copy_from_slice(src);
    }

    /// `self += other`
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    /// `self += alpha * other`
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * *b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// ‖self − other‖_F
    pub fn fro_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Rows `lo..hi` as a new matrix (copy).
    pub fn rows_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Transpose (copy).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `C = A·B` into a fresh matrix.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// `C = A·B`, writing into `c` (must be A.rows × B.cols; overwritten).
    ///
    /// Per output row: loop order k→j with k-blocking and a 4-way
    /// k-unroll — the inner j loop fuses four `c_row += a_ik·b_row`
    /// AXPYs, so each `c_row` load/store pass amortizes over 4 FMA
    /// streams (§Perf log: ~1.4× at layer shapes vs the single-k
    /// version). Output rows are independent, so large shapes run as
    /// row blocks on the [`crate::runtime::pool`] with unchanged bits.
    pub fn matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, b.cols));
        let n = b.cols;
        let k_tot = self.cols;
        let pool = pool::global();
        if pool.threads() == 1 || self.rows < 2 || self.rows * k_tot * n < PAR_GEMM_MIN {
            for i in 0..self.rows {
                gemm_row(
                    &self.data[i * k_tot..(i + 1) * k_tot],
                    b,
                    &mut c.data[i * n..(i + 1) * n],
                );
            }
            return;
        }
        pool::for_row_blocks(&pool, &mut c.data, n, |rows, block| {
            for (bi, i) in rows.enumerate() {
                gemm_row(
                    &self.data[i * k_tot..(i + 1) * k_tot],
                    b,
                    &mut block[bi * n..(bi + 1) * n],
                );
            }
        });
    }

    /// `C = Aᵀ·B` (A is self). Used for weight gradients `(P·H)ᵀ·M`.
    ///
    /// (AᵀB)[k, j] = Σ_i A[i,k]·B[i,j]: stream rows of A and B, AXPY
    /// into rows of C — same vector-friendly inner loop. Every element
    /// of C accumulates in i-ascending order; the parallel path gives
    /// each task a block of C rows (= columns of A) and replays the
    /// identical i-ascending stream, so serial and parallel agree
    /// bit-for-bit while the tasks move through B roughly in lockstep,
    /// sharing its cache footprint.
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_tn shape mismatch");
        let mut c = Mat::zeros(self.cols, b.cols);
        let n = b.cols;
        let k_tot = self.cols;
        let pool = pool::global();
        if pool.threads() == 1 || k_tot < 2 || self.rows * k_tot * n < PAR_GEMM_MIN {
            for i in 0..self.rows {
                let a_row = &self.data[i * k_tot..(i + 1) * k_tot];
                let b_row = &b.data[i * n..(i + 1) * n];
                for (k, &aik) in a_row.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let c_row = &mut c.data[k * n..(k + 1) * n];
                    for (cv, bv) in c_row.iter_mut().zip(b_row.iter()) {
                        *cv += aik * *bv;
                    }
                }
            }
            return c;
        }
        pool::for_row_blocks(&pool, &mut c.data, n, |ks, block| {
            for i in 0..self.rows {
                let a_row = &self.data[i * k_tot..(i + 1) * k_tot];
                let b_row = &b.data[i * n..(i + 1) * n];
                for k in ks.clone() {
                    let aik = a_row[k];
                    if aik == 0.0 {
                        continue;
                    }
                    let off = (k - ks.start) * n;
                    let c_row = &mut block[off..off + n];
                    for (cv, bv) in c_row.iter_mut().zip(b_row.iter()) {
                        *cv += aik * *bv;
                    }
                }
            }
        });
        c
    }

    /// `C = A·Bᵀ` (A is self). Used for feature gradients `M·Wᵀ`.
    ///
    /// Perf note (§Perf log): the natural dot-product formulation is a
    /// reduction the vectorizer handles poorly (~6 GFLOP/s); since `B` is
    /// always a small weight matrix on this path, transposing it first
    /// and reusing the streaming AXPY kernel is ~2× faster.
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt shape mismatch");
        let bt = b.transpose();
        self.matmul(&bt)
    }

    /// Horizontal concatenation `[self | b]`.
    pub fn hcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.rows, self.cols + b.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(b.row(r));
        }
        out
    }

    /// Vertical concatenation `[self; b]`.
    pub fn vcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&b.data);
        Mat { rows: self.rows + b.rows, cols: self.cols, data }
    }
}

/// Naive reference matmul for tests.
#[cfg(test)]
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    Mat::from_fn(a.rows, b.cols, |i, j| {
        (0..a.cols).map(|k| a.get(i, k) * b.get(k, j)).sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        prop::check("gemm==naive", 20, |rng| {
            let (m, k, n) = (
                1 + rng.gen_range(40),
                1 + rng.gen_range(90), // crosses KBLOCK
                1 + rng.gen_range(30),
            );
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            let c = a.matmul(&b);
            let r = matmul_naive(&a, &b);
            prop::assert_close(&c.data, &r.data, 1e-3)
        });
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        prop::check("tn==T*B", 10, |rng| {
            let (m, k, n) = (1 + rng.gen_range(20), 1 + rng.gen_range(20), 1 + rng.gen_range(20));
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(m, n, 1.0, rng);
            let c = a.matmul_tn(&b);
            let r = a.transpose().matmul(&b);
            prop::assert_close(&c.data, &r.data, 1e-3)
        });
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        prop::check("nt==A*T", 10, |rng| {
            let (m, k, n) = (1 + rng.gen_range(20), 1 + rng.gen_range(20), 1 + rng.gen_range(20));
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(n, k, 1.0, rng);
            let c = a.matmul_nt(&b);
            let r = a.matmul(&b.transpose());
            prop::assert_close(&c.data, &r.data, 1e-3)
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fro_dist_zero_iff_equal() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        assert_eq!(a.fro_dist(&a), 0.0);
        let mut b = a.clone();
        b.set(0, 0, b.get(0, 0) + 1.0);
        assert!((a.fro_dist(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn hcat_vcat_shapes() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![5., 6.]);
        let h = a.hcat(&b);
        assert_eq!((h.rows, h.cols), (2, 3));
        assert_eq!(h.row(0), &[1., 2., 5.]);
        let c = Mat::from_vec(1, 2, vec![7., 8.]);
        let v = a.vcat(&c);
        assert_eq!((v.rows, v.cols), (3, 2));
        assert_eq!(v.row(2), &[7., 8.]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Mat::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Mat::from_vec(1, 3, vec![1., 1., 1.]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3., 4., 5.]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2., 2.5]);
    }

    #[test]
    fn rows_range_copies() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let r = a.rows_range(1, 3);
        assert_eq!(r.data, vec![3., 4., 5., 6.]);
    }
}
