//! Activations, losses, dropout, and classification metrics.
//!
//! Loss heads follow the paper's experiment setup: softmax cross-entropy
//! for single-label datasets (Reddit-, products-like) and per-class
//! sigmoid BCE with micro-F1 for multi-label (Yelp-like). Dropout keeps an
//! explicit mask so the PipeGCN rule from Appendix F (apply dropout
//! *after* boundary communication, same mask in fwd/bwd) can be honored.

use super::dense::Mat;
use crate::runtime::pool;
use crate::util::rng::Rng;

/// Minimum element count before an elementwise pass goes to the pool.
const PAR_ELEM_MIN: usize = 1 << 14;

/// ReLU forward: `out = max(z, 0)`.
pub fn relu(z: &Mat) -> Mat {
    let mut out = z.clone();
    relu_inplace(&mut out);
    out
}

/// ReLU in place (parallel elementwise; one owner per element, so bits
/// are thread-count independent).
pub fn relu_inplace(z: &mut Mat) {
    let pool = pool::global();
    if pool.threads() == 1 || z.data.len() < PAR_ELEM_MIN {
        z.data.iter_mut().for_each(|x| *x = x.max(0.0));
        return;
    }
    pool::for_chunks(&pool, &mut z.data, |_, chunk| {
        chunk.iter_mut().for_each(|x| *x = x.max(0.0));
    });
}

/// ReLU backward in place: `g *= 1[z > 0]`.
pub fn relu_grad_inplace(g: &mut Mat, z: &Mat) {
    assert_eq!((g.rows, g.cols), (z.rows, z.cols));
    let pool = pool::global();
    if pool.threads() == 1 || g.data.len() < PAR_ELEM_MIN {
        for (gv, &zv) in g.data.iter_mut().zip(z.data.iter()) {
            if zv <= 0.0 {
                *gv = 0.0;
            }
        }
        return;
    }
    pool::for_chunks(&pool, &mut g.data, |start, chunk| {
        let zs = &z.data[start..start + chunk.len()];
        for (gv, &zv) in chunk.iter_mut().zip(zs.iter()) {
            if zv <= 0.0 {
                *gv = 0.0;
            }
        }
    });
}

/// Dropout mask with keep-prob `1-p`, inverted scaling (train-time only).
/// Returns the mask so backward can reuse it (Appendix F requirement).
/// Serial by design: the mask is a deterministic RNG stream.
pub fn dropout_mask(rows: usize, cols: usize, p: f32, rng: &mut Rng) -> Mat {
    assert!((0.0..1.0).contains(&p));
    let scale = 1.0 / (1.0 - p);
    Mat::from_fn(rows, cols, |_, _| if rng.bernoulli(p) { 0.0 } else { scale })
}

/// Elementwise product (dropout application; Hadamard in general).
pub fn hadamard(a: &Mat, b: &Mat) -> Mat {
    let mut out = a.clone();
    hadamard_inplace(&mut out, b);
    out
}

/// `a ∘= b` in place — the layer fwd/bwd dropout-apply path, saving a
/// full-matrix clone per application (parallel elementwise).
pub fn hadamard_inplace(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let pool = pool::global();
    if pool.threads() == 1 || a.data.len() < PAR_ELEM_MIN {
        for (o, &bv) in a.data.iter_mut().zip(b.data.iter()) {
            *o *= bv;
        }
        return;
    }
    pool::for_chunks(&pool, &mut a.data, |start, chunk| {
        let bs = &b.data[start..start + chunk.len()];
        for (o, &bv) in chunk.iter_mut().zip(bs.iter()) {
            *o *= bv;
        }
    });
}

/// Softmax cross-entropy over rows listed in `mask` (training nodes).
///
/// Returns `(mean loss, dL/dlogits)` where the gradient is already divided
/// by `mask.len()` and rows outside the mask have zero gradient.
pub fn softmax_xent(logits: &Mat, labels: &[u32], mask: &[u32]) -> (f64, Mat) {
    assert_eq!(logits.rows, labels.len());
    let mut grad = Mat::zeros(logits.rows, logits.cols);
    if mask.is_empty() {
        return (0.0, grad);
    }
    let inv_n = 1.0 / mask.len() as f32;
    let mut loss = 0.0f64;
    // shifted-exp row cache: exp() runs once per element — the
    // normalizer and the probabilities reuse the same values, with the
    // same fold order, so loss and gradient bits are unchanged
    let mut exps = vec![0.0f32; logits.cols];
    for &r in mask {
        let r = r as usize;
        let row = logits.row(r);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (e, &v) in exps.iter_mut().zip(row.iter()) {
            *e = (v - m).exp();
            z += *e;
        }
        let y = labels[r] as usize;
        debug_assert!(y < logits.cols);
        loss += (z.ln() - (row[y] - m)) as f64;
        let g = grad.row_mut(r);
        for (c, &e) in exps.iter().enumerate() {
            let p = e / z;
            g[c] = (p - if c == y { 1.0 } else { 0.0 }) * inv_n;
        }
    }
    (loss / mask.len() as f64, grad)
}

/// Multi-label sigmoid binary cross-entropy over `mask` rows.
/// `targets` is a rows×cols {0,1} matrix. Returns `(mean loss, grad)`.
pub fn sigmoid_bce(logits: &Mat, targets: &Mat, mask: &[u32]) -> (f64, Mat) {
    assert_eq!((logits.rows, logits.cols), (targets.rows, targets.cols));
    let mut grad = Mat::zeros(logits.rows, logits.cols);
    if mask.is_empty() {
        return (0.0, grad);
    }
    let denom = (mask.len() * logits.cols) as f64;
    let inv = 1.0 / denom as f32;
    let mut loss = 0.0f64;
    for &r in mask {
        let r = r as usize;
        let x_row = logits.row(r);
        let t_row = targets.row(r);
        let g_row = grad.row_mut(r);
        for c in 0..x_row.len() {
            let x = x_row[c];
            let t = t_row[c];
            // numerically stable: log(1+e^-|x|) + max(x,0) - t*x
            loss += (x.max(0.0) - x * t + (1.0 + (-x.abs()).exp()).ln()) as f64;
            let s = 1.0 / (1.0 + (-x).exp());
            g_row[c] = (s - t) * inv;
        }
    }
    (loss / denom, grad)
}

/// Single-label accuracy over `mask` rows.
pub fn accuracy(logits: &Mat, labels: &[u32], mask: &[u32]) -> f64 {
    if mask.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for &r in mask {
        let r = r as usize;
        let row = logits.row(r);
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = c;
            }
        }
        if best as u32 == labels[r] {
            correct += 1;
        }
    }
    correct as f64 / mask.len() as f64
}

/// Counts for micro-F1 (so partitions can be aggregated before the divide).
#[derive(Default, Clone, Copy, Debug)]
pub struct F1Counts {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl F1Counts {
    pub fn merge(&mut self, o: F1Counts) {
        self.tp += o.tp;
        self.fp += o.fp;
        self.fn_ += o.fn_;
    }

    pub fn micro_f1(&self) -> f64 {
        let denom = 2 * self.tp + self.fp + self.fn_;
        if denom == 0 {
            0.0
        } else {
            2.0 * self.tp as f64 / denom as f64
        }
    }
}

/// Micro-F1 counts for multi-label predictions (threshold at logit 0 ⇔ p=0.5).
pub fn f1_counts(logits: &Mat, targets: &Mat, mask: &[u32]) -> F1Counts {
    let mut c = F1Counts::default();
    for &r in mask {
        let r = r as usize;
        let x_row = logits.row(r);
        let t_row = targets.row(r);
        for k in 0..x_row.len() {
            let pred = x_row[k] > 0.0;
            let tru = t_row[k] > 0.5;
            match (pred, tru) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
                _ => {}
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn relu_basic() {
        let z = Mat::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(relu(&z).data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn relu_grad_masks() {
        let z = Mat::from_vec(1, 3, vec![-1.0, 1.0, 0.0]);
        let mut g = Mat::from_vec(1, 3, vec![5.0, 5.0, 5.0]);
        relu_grad_inplace(&mut g, &z);
        assert_eq!(g.data, vec![0.0, 5.0, 0.0]);
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        // zero logits, C classes -> loss = ln C
        let logits = Mat::zeros(2, 4);
        let labels = vec![1, 2];
        let (loss, grad) = softmax_xent(&logits, &labels, &[0, 1]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-6);
        // gradient rows sum to zero
        for r in 0..2 {
            let s: f32 = grad.row(r).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_grad_matches_fd() {
        prop::check("xent fd", 5, |rng| {
            let n = 3;
            let c = 4;
            let logits = Mat::randn(n, c, 1.0, rng);
            let labels: Vec<u32> = (0..n).map(|_| rng.gen_range(c) as u32).collect();
            let mask: Vec<u32> = (0..n as u32).collect();
            let (_, grad) = softmax_xent(&logits, &labels, &mask);
            let eps = 1e-3f32;
            for r in 0..n {
                for k in 0..c {
                    let mut lp = logits.clone();
                    lp.set(r, k, lp.get(r, k) + eps);
                    let mut lm = logits.clone();
                    lm.set(r, k, lm.get(r, k) - eps);
                    let (fp_, _) = softmax_xent(&lp, &labels, &mask);
                    let (fm, _) = softmax_xent(&lm, &labels, &mask);
                    let fd = ((fp_ - fm) / (2.0 * eps as f64)) as f32;
                    prop_assert!(
                        (fd - grad.get(r, k)).abs() < 2e-2,
                        "fd {} vs grad {}",
                        fd,
                        grad.get(r, k)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bce_grad_matches_fd() {
        prop::check("bce fd", 5, |rng| {
            let (n, c) = (2, 3);
            let logits = Mat::randn(n, c, 1.0, rng);
            let targets = Mat::from_fn(n, c, |_, _| if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
            let mask: Vec<u32> = (0..n as u32).collect();
            let (_, grad) = sigmoid_bce(&logits, &targets, &mask);
            let eps = 1e-3f32;
            for r in 0..n {
                for k in 0..c {
                    let mut lp = logits.clone();
                    lp.set(r, k, lp.get(r, k) + eps);
                    let mut lm = logits.clone();
                    lm.set(r, k, lm.get(r, k) - eps);
                    let (fp_, _) = sigmoid_bce(&lp, &targets, &mask);
                    let (fm, _) = sigmoid_bce(&lm, &targets, &mask);
                    let fd = ((fp_ - fm) / (2.0 * eps as f64)) as f32;
                    prop_assert!(
                        (fd - grad.get(r, k)).abs() < 2e-2,
                        "fd {} vs grad {}",
                        fd,
                        grad.get(r, k)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn accuracy_counts() {
        let logits = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        let labels = vec![0, 1, 1];
        assert!((accuracy(&logits, &labels, &[0, 1, 2]) - 2.0 / 3.0).abs() < 1e-9);
        assert!((accuracy(&logits, &labels, &[0, 1]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn f1_perfect_and_zero() {
        let t = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let good = Mat::from_vec(2, 2, vec![5.0, -5.0, -5.0, 5.0]);
        assert!((f1_counts(&good, &t, &[0, 1]).micro_f1() - 1.0).abs() < 1e-9);
        let bad = Mat::from_vec(2, 2, vec![-5.0, 5.0, 5.0, -5.0]);
        assert_eq!(f1_counts(&bad, &t, &[0, 1]).micro_f1(), 0.0);
    }

    #[test]
    fn f1_counts_merge_equivalent() {
        let t = Mat::from_vec(2, 2, vec![1.0, 0.0, 1.0, 1.0]);
        let x = Mat::from_vec(2, 2, vec![1.0, 1.0, -1.0, 2.0]);
        let whole = f1_counts(&x, &t, &[0, 1]);
        let mut parts = f1_counts(&x, &t, &[0]);
        parts.merge(f1_counts(&x, &t, &[1]));
        assert_eq!(whole.tp, parts.tp);
        assert_eq!(whole.fp, parts.fp);
        assert_eq!(whole.fn_, parts.fn_);
    }

    #[test]
    fn dropout_mask_stats() {
        let mut rng = Rng::new(1);
        let m = dropout_mask(100, 100, 0.5, &mut rng);
        let zeros = m.data.iter().filter(|&&x| x == 0.0).count();
        let frac = zeros as f64 / m.data.len() as f64;
        assert!((frac - 0.5).abs() < 0.03, "zero frac {frac}");
        // kept entries are scaled by 1/(1-p)
        assert!(m.data.iter().all(|&x| x == 0.0 || (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn empty_mask_zero_loss() {
        let logits = Mat::zeros(2, 2);
        let (loss, grad) = softmax_xent(&logits, &[0, 0], &[]);
        assert_eq!(loss, 0.0);
        assert_eq!(grad.data, vec![0.0; 4]);
    }
}
