//! Tensor substrate: dense row-major f32 matrices with cache-blocked GEMM,
//! CSR sparse matrices with row-parallel SpMM, and the activation / loss
//! kernels the GCN layers need.
//!
//! This is the compute engine behind the **native** backend
//! (`runtime::native`); the **xla** backend runs the same math from AOT
//! HLO artifacts and is cross-checked against this implementation.
//!
//! Threading model: the hot-path kernels (SpMM, the GEMM variants, and
//! the elementwise passes) dispatch to [`crate::runtime::pool`] over
//! **disjoint output-row blocks**. Row ownership means every output
//! element is summed by exactly one task in the serial order, so kernel
//! results — and therefore whole training runs — are bit-identical at
//! any `--threads` count (pinned by `tests/parallel_kernels.rs`).

pub mod dense;
pub mod sparse;
pub mod ops;

pub use dense::Mat;
pub use sparse::Csr;
