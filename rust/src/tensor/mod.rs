//! Tensor substrate: dense row-major f32 matrices with cache-blocked GEMM,
//! CSR sparse matrices with row-parallel SpMM, and the activation / loss
//! kernels the GCN layers need.
//!
//! This is the compute engine behind the **native** backend
//! (`runtime::native`); the **xla** backend runs the same math from AOT
//! HLO artifacts and is cross-checked against this implementation.

pub mod dense;
pub mod sparse;
pub mod ops;

pub use dense::Mat;
pub use sparse::Csr;
