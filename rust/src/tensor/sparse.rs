//! CSR sparse matrices and the SpMM kernels used for GCN propagation.
//!
//! The per-partition propagation matrix `P_i` (rows = inner nodes,
//! columns = inner + boundary nodes) is stored in CSR; the forward pass
//! computes `P·H` and the backward pass `Pᵀ·M`. Both kernels stream the
//! dense right-hand side row-wise so the inner loop is a contiguous AXPY.
//!
//! Threading: [`Csr::spmm_into`] runs as disjoint output-row blocks on
//! [`crate::runtime::pool`] — one owner per output row, serial
//! summation order per row, bit-identical at any thread count. The
//! scatter-form `spmm_t_into` has multi-owner writes and stays serial;
//! the training backward instead goes through the precomputed transpose
//! (`runtime::native` caches `P.transpose()`), which runs as a
//! row-parallel *gather* through the same `spmm_into`.

use super::dense::Mat;
use crate::runtime::pool;

/// Minimum `nnz × rhs-cols` before an SpMM goes to the pool.
const PAR_SPMM_MIN: usize = 1 << 14;

#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl Csr {
    pub fn zeros(rows: usize, cols: usize) -> Csr {
        Csr { rows, cols, indptr: vec![0; rows + 1], indices: Vec::new(), data: Vec::new() }
    }

    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trip: Vec<(u32, u32, f32)>) -> Csr {
        trip.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(trip.len());
        let mut data: Vec<f32> = Vec::with_capacity(trip.len());
        let mut last: Option<(u32, u32)> = None;
        for (r, c, v) in trip {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            if last == Some((r, c)) {
                *data.last_mut().unwrap() += v;
            } else {
                indptr[r as usize + 1] += 1;
                indices.push(c);
                data.push(v);
                last = Some((r, c));
            }
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Csr { rows, cols, indptr, indices, data }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn row_entries(&self, r: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .zip(self.data[lo..hi].iter())
            .map(|(&c, &v)| (c as usize, v))
    }

    /// `out = self · h` (out: rows × h.cols). Allocates.
    pub fn spmm(&self, h: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, h.cols);
        self.spmm_into(h, &mut out);
        out
    }

    /// `out = self · h`, overwriting `out`. Row-parallel on the pool for
    /// large shapes (each output row has one owner — bit-identical to
    /// the serial path at any thread count).
    pub fn spmm_into(&self, h: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, h.rows, "spmm shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, h.cols));
        let n = h.cols;
        let pool = pool::global();
        if pool.threads() == 1 || self.rows < 2 || self.nnz() * n < PAR_SPMM_MIN {
            for r in 0..self.rows {
                self.spmm_row(r, h, &mut out.data[r * n..(r + 1) * n]);
            }
            return;
        }
        pool::for_row_blocks(&pool, &mut out.data, n, |rows, block| {
            for (bi, r) in rows.enumerate() {
                self.spmm_row(r, h, &mut block[bi * n..(bi + 1) * n]);
            }
        });
    }

    /// One output row of `self · h` — the shared row kernel that fixes
    /// the summation order for the serial and parallel paths. Crate-
    /// visible so the serving tier's activation cache can recompute a
    /// row subset bit-identically to a full [`Csr::spmm`] pass.
    #[inline]
    pub(crate) fn spmm_row(&self, r: usize, h: &Mat, out_row: &mut [f32]) {
        let n = h.cols;
        out_row.iter_mut().for_each(|x| *x = 0.0);
        for idx in self.indptr[r]..self.indptr[r + 1] {
            let c = self.indices[idx] as usize;
            let v = self.data[idx];
            let h_row = &h.data[c * n..(c + 1) * n];
            for (o, x) in out_row.iter_mut().zip(h_row.iter()) {
                *o += v * *x;
            }
        }
    }

    /// `out = selfᵀ · m` (out: cols × m.cols). Scatter formulation:
    /// each CSR entry (r, c, v) contributes `v · m[r,:]` to `out[c,:]`.
    /// Multi-owner writes, so it stays serial — hot paths use the
    /// precomputed transpose + [`Csr::spmm`] gather instead.
    pub fn spmm_t(&self, m: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, m.cols);
        self.spmm_t_into(m, &mut out);
        out
    }

    pub fn spmm_t_into(&self, m: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, m.rows, "spmm_t shape mismatch");
        assert_eq!((out.rows, out.cols), (self.cols, m.cols));
        let n = m.cols;
        out.data.iter_mut().for_each(|x| *x = 0.0);
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            let m_row = &m.data[r * n..(r + 1) * n];
            for idx in lo..hi {
                let c = self.indices[idx] as usize;
                let v = self.data[idx];
                let out_row = &mut out.data[c * n..(c + 1) * n];
                for (o, x) in out_row.iter_mut().zip(m_row.iter()) {
                    *o += v * *x;
                }
            }
        }
    }

    /// Materialized transpose (for tests and the explicit-Pᵀ path).
    pub fn transpose(&self) -> Csr {
        let mut trip = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            for (c, v) in self.row_entries(r) {
                trip.push((c as u32, r as u32, v));
            }
        }
        Csr::from_triplets(self.cols, self.rows, trip)
    }

    /// Densify (tests / XLA artifact inputs for small partitions).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_entries(r) {
                out.data[r * self.cols + c] += v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f32) -> Csr {
        let mut trip = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    trip.push((r as u32, c as u32, rng.normal()));
                }
            }
        }
        Csr::from_triplets(rows, cols, trip)
    }

    #[test]
    fn from_triplets_sums_duplicates() {
        let c = Csr::from_triplets(2, 2, vec![(0, 1, 1.0), (0, 1, 2.0), (1, 0, 5.0)]);
        assert_eq!(c.nnz(), 2);
        let d = c.to_dense();
        assert_eq!(d.get(0, 1), 3.0);
        assert_eq!(d.get(1, 0), 5.0);
    }

    #[test]
    fn spmm_matches_dense() {
        prop::check("spmm==dense", 15, |rng| {
            let (r, c, f) = (1 + rng.gen_range(30), 1 + rng.gen_range(30), 1 + rng.gen_range(16));
            let s = random_csr(rng, r, c, 0.2);
            let h = Mat::randn(c, f, 1.0, rng);
            let got = s.spmm(&h);
            let want = s.to_dense().matmul(&h);
            prop::assert_close(&got.data, &want.data, 1e-3)
        });
    }

    #[test]
    fn spmm_t_matches_transpose_spmm() {
        prop::check("spmm_t==T.spmm", 15, |rng| {
            let (r, c, f) = (1 + rng.gen_range(30), 1 + rng.gen_range(30), 1 + rng.gen_range(16));
            let s = random_csr(rng, r, c, 0.2);
            let m = Mat::randn(r, f, 1.0, rng);
            let got = s.spmm_t(&m);
            let want = s.transpose().spmm(&m);
            prop::assert_close(&got.data, &want.data, 1e-3)
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let s = random_csr(&mut rng, 10, 7, 0.3);
        assert_eq!(s.transpose().transpose(), s);
    }

    #[test]
    fn empty_rows_ok() {
        let s = Csr::from_triplets(3, 3, vec![(1, 1, 2.0)]);
        let h = Mat::from_vec(3, 1, vec![1.0, 10.0, 100.0]);
        let out = s.spmm(&h);
        assert_eq!(out.data, vec![0.0, 20.0, 0.0]);
    }

    #[test]
    fn zeros_matrix() {
        let s = Csr::zeros(2, 2);
        assert_eq!(s.nnz(), 0);
        let h = Mat::from_vec(2, 2, vec![1.0; 4]);
        assert_eq!(s.spmm(&h).data, vec![0.0; 4]);
    }
}
